"""Parity tests for the delivery-wheel Pallas kernels (kernels.wheel).

Every kernel runs here in `interpret=True` mode against its XLA-path
reference — the reference IS the semantics (DESIGN.md §Kernels), so the
contract is bit-identical equality, not tolerance. The suite closes the
loop at three levels:

  * kernel vs reference on adversarial standalone inputs (padding,
    ragged tails, multi-block grids);
  * reference vs the engine's own formulation (`descent_reference` vs
    `deliver_network_step`, `_common.in_segment` vs
    `JaxEngine._in_segment`) — the standalone mirrors may not drift;
  * engine trajectories with kernels ON (`kernel="pallas"`, interpret
    on CPU) vs OFF (`kernel="ref"`) — full `DeviceState` equality
    through deferral pressure and churn.

CPU CI runs all of this in the fast suite (the `pallas` marker selects
just these: ``-m pallas``).
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dht import Ring
from repro.engine import protocol as proto
from repro.engine.jax_backend import (NDIR, JaxEngine, deliver_network_step)
from repro.engine.problems import get_problem
from repro.kernels.wheel import WHEEL_KERNELS
from repro.kernels.wheel._common import in_segment
from repro.kernels.wheel.descent import descent_reference, descent_tail_kernel
from repro.kernels.wheel.due_dedup import (due_dedup_kernel,
                                           due_dedup_reference)
from repro.kernels.wheel.enqueue import (stage_rows_kernel,
                                         stage_rows_reference)
from repro.kernels.wheel.threshold_step import threshold_step_kernel

pytestmark = pytest.mark.pallas


def _eq(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


# -- threshold_step: problem-generic fused margin/test/Send ---------------

@pytest.mark.parametrize("problem,dw", [("majority", 1), ("mean", 1),
                                        ("l2", 2)])
@pytest.mark.parametrize("n", [8, 100, 2048 + 17])
def test_threshold_step_matches_rules(problem, dw, n):
    p = get_problem(problem)
    pw = p.payload_width
    rng = np.random.default_rng(n * 7 + dw)
    in_pay = jnp.asarray(rng.integers(-40, 41, (n, NDIR, pw)), jnp.int32)
    out_pay = jnp.asarray(rng.integers(-40, 41, (n, NDIR, pw)), jnp.int32)
    x = jnp.asarray(rng.integers(-300, 301, (n, p.data_width)), jnp.int32)
    want = proto.threshold_rules(p, jnp, in_pay, out_pay, x)
    got = threshold_step_kernel(p, in_pay, out_pay, x, block=256,
                                interpret=True)
    for w, g, name in zip(want, got, ("viol", "out", "pay")):
        _eq(g, w, f"{problem} {name}")


def test_threshold_step_l2_consts_roundtrip():
    """L2's direction cover rides as an explicit kernel input
    (test_consts); test_with_consts must reproduce test() exactly."""
    p = get_problem("l2")
    rng = np.random.default_rng(0)
    agg = jnp.asarray(rng.integers(-500, 501, (33, NDIR, 3)), jnp.int32)
    k = jnp.asarray(rng.integers(-500, 501, (33, 3)), jnp.int32)
    consts = tuple(p.test_consts(jnp))
    assert len(consts) == 1 and consts[0].shape == p.U.shape
    want = p.test(jnp, agg, k)
    got = p.test_with_consts(jnp, agg, k, consts)
    _eq(got[0], want[0])
    _eq(got[1], want[1])


# -- due_dedup: window-local winner/representative/force election ---------

def _dedup_inputs(ww, nl, seed, alert_frac=0.2):
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(rng.integers(0, nl, ww), jnp.int32)
    acc = rng.random(ww) < 0.6
    is_alert = rng.random(ww) < alert_frac
    acc_d = jnp.asarray(acc & ~is_alert)
    acc_a = jnp.asarray(acc & is_alert)
    w_seq = jnp.asarray(rng.integers(0, 50, ww), jnp.int32)
    link_seq = jnp.asarray(rng.integers(0, 50, ww), jnp.int32)
    return flat, acc_d, acc_a, w_seq, link_seq


@pytest.mark.parametrize("ww,block", [(64, 64), (100, 32), (576, 512),
                                      (576, 128)])
@pytest.mark.parametrize("seed", [0, 3])
def test_due_dedup_matches_plane(ww, block, seed):
    # few links => heavy collisions: the dedup election actually works
    nl = max(ww // 3, NDIR)
    args = _dedup_inputs(ww, nl, seed)
    want = due_dedup_reference(*args, nl=nl)
    got = due_dedup_kernel(*args, block=block, interpret=True)
    names = ("winner", "loser", "fresh", "alert_write", "is_rep", "aforce")
    for w, g, name in zip(want, got, names):
        _eq(g, w, f"ww={ww} block={block} {name}")


def test_due_dedup_no_alerts():
    """All-data windows (the steady-state cycle) still elect correctly."""
    ww, nl = 128, 24
    flat, acc_d, _, w_seq, link_seq = _dedup_inputs(ww, nl, 11, alert_frac=0)
    acc_a = jnp.zeros(ww, bool)
    want = due_dedup_reference(flat, acc_d, acc_a, w_seq, link_seq, nl=nl)
    got = due_dedup_kernel(flat, acc_d, acc_a, w_seq, link_seq,
                           block=64, interpret=True)
    for w, g in zip(want, got):
        _eq(g, w)
    assert not np.asarray(got[3]).any()  # no alert_write without alerts


# -- stage_rows: ordinal-keyed delay classes + DELIVER_T stamping ---------

@pytest.mark.parametrize("m,roww", [(2304, 8), (2310, 9), (40, 8)])
def test_stage_rows_matches_reference(m, roww):
    rng = np.random.default_rng(m)
    rows = jnp.asarray(
        rng.integers(0, 2**32, (m, roww), dtype=np.uint64).astype(np.uint32))
    alert = jnp.asarray(rng.random(m) < 0.15)
    mask = rng.random(m) < 0.6
    # ordinal as the engine builds it: rank of the row among the live
    # rows of its staging block (-1 before the first live row)
    ordinal = jnp.asarray(np.cumsum(mask.astype(np.int32)) - 1)
    perm = jnp.asarray(rng.permutation(10) + 1, jnp.int32)
    t = jnp.asarray(97, jnp.int32)
    dt_col = roww - 1
    want = stage_rows_reference(rows, alert, ordinal, perm, t, dt_col)
    got = stage_rows_kernel(rows, alert, ordinal, perm, t, dt_col,
                            interpret=True)
    _eq(got, want, "staged")
    # and the reference must equal the stated semantics row by row
    wn = np.asarray(want)
    on = np.asarray(ordinal)
    an = np.asarray(alert)
    pn = np.asarray(perm)
    _eq(wn[:, :dt_col], np.asarray(rows)[:, :dt_col], "non-DT columns")
    due = np.where(an, 97 + 1, 97 + pn[on % 10]).astype(np.uint32)
    _eq(wn[:, dt_col], due, "DELIVER_T semantics")


# -- descent: the R1 internal-descent tail --------------------------------

def _descent_inputs(m, seed=0, d=16, n=64):
    """Routing-consistent inputs from a real ring (owner tables the way
    the cycle builds them)."""
    rng = np.random.default_rng(seed)
    ring = Ring.random(n, d, seed=seed + 1)
    votes = rng.integers(0, 2, n)
    eng = JaxEngine(ring, votes, seed=seed, kernel="ref")
    st = eng._st
    dest = jnp.asarray(
        rng.integers(0, 2**d, m, dtype=np.uint64).astype(np.uint32))
    origin = jnp.asarray(np.asarray(st.addrs)[rng.integers(0, n, m)])
    owner = eng._owner_of(st.addrs, st.n_live, dest)
    a_prev, a_self = st.prev[owner], st.addrs[owner]
    kw = dict(
        origin=origin, dest=dest,
        edge=jnp.asarray(rng.integers(0, 2**d, m, dtype=np.uint64)
                         .astype(np.uint32)),
        has_edge=jnp.asarray(rng.random(m) < 0.7),
        live=jnp.asarray(rng.random(m) < 0.8),
        entry=jnp.asarray(rng.random(m) < 0.5),
        pos_i=st.pos[owner], a_prev=a_prev, a_self=a_self,
        self_seg=JaxEngine._in_segment(origin, a_prev, a_self),
        max_addr=st.addrs[st.n_live - 1],
    )
    return kw, d


@pytest.mark.parametrize("m,block", [(64, 64), (200, 64)])
def test_descent_tail_kernel_matches_reference(m, block):
    kw, d = _descent_inputs(m, seed=m)
    args = (kw["origin"], kw["dest"], kw["edge"], kw["has_edge"], kw["live"],
            kw["entry"], kw["pos_i"], kw["a_prev"], kw["a_self"],
            kw["self_seg"], kw["max_addr"])
    want = descent_reference(*args, d=d)
    got = descent_tail_kernel(*args, d=d, block=block, interpret=True)
    for w, g, name in zip(want, got, ("acc", "drop", "o_dest", "o_edge",
                                      "o_he")):
        _eq(g, w, f"m={m} block={block} {name}")


def test_descent_reference_is_deliver_network_step():
    """The standalone reference may not drift from the engine's
    `deliver_network_step` — identical loop on identical inputs."""
    kw, d = _descent_inputs(150, seed=5)
    want = deliver_network_step(d=d, **kw)
    got = descent_reference(
        kw["origin"], kw["dest"], kw["edge"], kw["has_edge"], kw["live"],
        kw["entry"], kw["pos_i"], kw["a_prev"], kw["a_self"],
        kw["self_seg"], kw["max_addr"], d=d)
    for w, g in zip(want, got):
        _eq(g, w)


def test_common_in_segment_matches_engine():
    rng = np.random.default_rng(2)
    addr, a_prev, a_self = (
        jnp.asarray(rng.integers(0, 2**32, 4096, dtype=np.uint64)
                    .astype(np.uint32)) for _ in range(3))
    _eq(in_segment(addr, a_prev, a_self),
        JaxEngine._in_segment(addr, a_prev, a_self))


# -- engine level: kernels ON vs OFF, full-state equality -----------------

def _state_equal(ref, ker, tag):
    for f in ref._st._fields:
        _eq(getattr(ker._st, f), getattr(ref._st, f), f"{tag}: {f}")


def _pair(ring, votes, problem="majority", **kw):
    ref = JaxEngine(ring, votes, seed=9, problem=problem, kernel="ref", **kw)
    ker = JaxEngine(ring, votes, seed=9, problem=problem, kernel="pallas",
                    **kw)
    assert ker._wk == frozenset(WHEEL_KERNELS)
    assert not ref._wk
    return ref, ker


@pytest.mark.parametrize("problem", ["majority", "mean", "l2"])
def test_engine_wheel_kernels_bit_identical(problem):
    rng = np.random.default_rng(3)
    n = 48
    ring = Ring.random(n, d=16, seed=5)
    if problem == "majority":
        votes = rng.integers(0, 2, n)
    elif problem == "mean":
        votes = rng.integers(-8, 9, (n, 1))
    else:
        votes = rng.normal(0, 1.0, (n, 2))  # mixed inside/outside: traffic
    ref, ker = _pair(ring, votes, problem)
    for step in range(4):
        ref.step(cycles=3)
        ker.step(cycles=3)
        _state_equal(ref, ker, f"{problem} step {step}")
        _eq(ker.outputs(), ref.outputs())


def test_engine_wheel_kernels_under_deferral_and_churn():
    """Tiny work_budget forces slips/revolution waits (the LATE-bit
    accounting path) and joins/leaves force alerts (the aforce path) —
    kernels must track the XLA trajectory through both."""
    n = 200
    rng = np.random.default_rng(1)
    votes = rng.integers(0, 2, n)
    ring = Ring.random(n, d=18, seed=2)
    ref, ker = _pair(ring, votes, work_budget=32)
    for step in range(8):
        ref.step(cycles=2)
        ker.step(cycles=2)
        _state_equal(ref, ker, f"defer step {step}")
    assert ref.deferred > 0  # the budget squeeze actually engaged
    assert ref.deferral_rate == ker.deferral_rate > 0

    ref, ker = _pair(ring, votes)
    ref.step(cycles=2)
    ker.step(cycles=2)
    for i, a in enumerate((1234567, 424242)):
        ref.join(a)
        ker.join(a)
        ref.step(cycles=4)
        ker.step(cycles=4)
        _state_equal(ref, ker, f"join {i}")
    ref.leave(3)
    ker.leave(3)
    ref.step(cycles=6)
    ker.step(cycles=6)
    _state_equal(ref, ker, "leave")


def test_engine_wheel_kernel_subset_and_validation():
    """`wheel_kernels` selects individual kernels (each has its own
    fallback flag); unknown names fail fast."""
    n = 32
    rng = np.random.default_rng(4)
    votes = rng.integers(0, 2, n)
    ring = Ring.random(n, d=16, seed=7)
    ref = JaxEngine(ring, votes, seed=3, kernel="ref")
    one = JaxEngine(ring, votes, seed=3, kernel="pallas",
                    wheel_kernels=("enqueue",))
    assert one._wk == {"enqueue"}
    ref.step(cycles=4)
    one.step(cycles=4)
    _state_equal(ref, one, "enqueue-only")
    off = JaxEngine(ring, votes, seed=3, kernel="pallas",
                    wheel_kernels="none")
    assert not off._wk
    with pytest.raises(ValueError, match="unknown wheel kernels"):
        JaxEngine(ring, votes, seed=3, wheel_kernels=("bogus",))


def test_deferred_counts_each_row_once():
    """The LATE bit stops the historical standing-backlog recount:
    deferred must stay well below (backlog x residence-cycles)."""
    n = 200
    rng = np.random.default_rng(8)
    votes = rng.integers(0, 2, n)
    ring = Ring.random(n, d=18, seed=3)
    eng = JaxEngine(ring, votes, seed=1, kernel="ref", work_budget=32)
    eng.step(cycles=1)  # init storm lands in the wheel
    # budget is per lane now: a (lane, slot) cell above lane_budget
    # must wait for a later cycle
    backlog = max(int(np.asarray(eng._st.wcnt).max()) - eng.lane_budget, 0)
    assert backlog > 0, "config must actually overflow the budget"
    eng.step(cycles=30)
    # once-per-row: bounded by total rows ever enqueued (~3n + resends),
    # NOT by backlog x 30 cycles of residence
    assert eng.deferred < 3 * n + eng.messages_sent
    assert eng.deferral_rate == eng.deferred / eng.messages_sent
