"""RG-LRU scan kernel: shape/dtype sweep vs associative-scan oracle; VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rglru.ops import linear_scan
from repro.kernels.rglru.ref import linear_scan_reference, rglru_gates
from repro.kernels.rglru.rglru import rglru_scan

rng = np.random.default_rng(1)

SWEEP = [
    (2, 64, 128, jnp.float32),
    (1, 256, 256, jnp.float32),
    (2, 100, 96, jnp.float32),   # non-power-of-two
    (1, 128, 128, jnp.bfloat16),
    (3, 17, 8, jnp.float32),     # tiny
]


@pytest.mark.parametrize("case", SWEEP)
def test_kernel_matches_reference(case):
    b, t, w, dt = case
    a = jnp.asarray(rng.uniform(0.7, 0.999, (b, t, w)), dt)
    u = jnp.asarray(rng.standard_normal((b, t, w)) * 0.1, dt)
    h0 = jnp.asarray(rng.standard_normal((b, w)) * 0.1, dt)
    hk, hlk = rglru_scan(a, u, h0, interpret=True)
    hr, hlr = linear_scan_reference(a, u, h0)
    tol = 5e-2 if dt == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(hk, np.float32),
                               np.asarray(hr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(hlk, np.float32),
                               np.asarray(hlr, np.float32), atol=tol)


def test_custom_vjp_matches_reference_grads():
    b, t, w = 1, 48, 16
    a = jnp.asarray(rng.uniform(0.8, 0.99, (b, t, w)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((b, t, w)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, w)), jnp.float32)

    def f(a, u, h0):
        h, hl = linear_scan(a, u, h0, False)
        return (h ** 2).sum() + hl.sum()

    def fr(a, u, h0):
        h, hl = linear_scan_reference(a, u, h0)
        return (h ** 2).sum() + hl.sum()

    g1 = jax.grad(f, argnums=(0, 1, 2))(a, u, h0)
    g2 = jax.grad(fr, argnums=(0, 1, 2))(a, u, h0)
    for x, y in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=5e-4)


def test_gates_shape_and_range():
    b, t, w = 2, 8, 16
    x = jnp.asarray(rng.standard_normal((b, t, w)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((b, t, w)), jnp.float32)
    i = jnp.asarray(rng.standard_normal((b, t, w)), jnp.float32)
    lam = jnp.asarray(rng.uniform(2, 7, (w,)), jnp.float32)
    a_t, u_t = rglru_gates(x, r, i, lam)
    assert a_t.shape == (b, t, w)
    assert bool((a_t > 0).all()) and bool((a_t <= 1).all())
    assert bool(jnp.isfinite(u_t).all())


def test_scan_composition():
    """Scanning [0:t1] then [t1:] from the carried state == full scan."""
    b, t, w = 2, 64, 32
    a = jnp.asarray(rng.uniform(0.7, 0.999, (b, t, w)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((b, t, w)), jnp.float32)
    h_full, hl_full = linear_scan_reference(a, u, None)
    h1, hl1 = linear_scan_reference(a[:, :40], u[:, :40], None)
    h2, hl2 = linear_scan_reference(a[:, 40:], u[:, 40:], hl1)
    np.testing.assert_allclose(np.asarray(h_full[:, 40:]), np.asarray(h2),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl_full), np.asarray(hl2), atol=1e-5)
