"""Per-architecture smoke tests: one forward/train step on CPU, output
shapes, finiteness; decode==teacher-forced-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import shapes_for, sub_quadratic
from repro.models.model import (
    decode_step, forward, init_params, lm_loss, make_cache,
)


def _inputs(cfg, b, s, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    fe = None
    if cfg.frontend:
        fe = jnp.asarray(
            rng.standard_normal((b, cfg.n_frontend_tokens, cfg.frontend_dim)),
            jnp.float32,
        )
    return toks, fe


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    rng = np.random.default_rng(0)
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, fe = _inputs(cfg, 2, 16, rng)
    logits = forward(params, cfg, toks, fe, mode="train")
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, toks, toks, fe)
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(float(loss)) and np.isfinite(gn), arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    rng = np.random.default_rng(1)
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity dropping is batch-size dependent; disable drops for the
        # consistency check (the drop path is covered by test_moe_* below)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, fe = _inputs(cfg, 2, 12, rng)
    _, cache = forward(params, cfg, toks, fe, mode="prefill", cache_len=24)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 1)), jnp.int32)
    dec, _ = decode_step(params, cfg, nxt, cache)
    full = forward(params, cfg, jnp.concatenate([toks, nxt], 1), fe,
                   mode="train")
    scale = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9
    err = float(jnp.max(jnp.abs(dec[:, 0] - full[:, -1]))) / scale
    assert err < 5e-4, f"{arch}: decode diverges from forward ({err})"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-135m", "xlstm-350m"])
def test_loss_decreases(arch):
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamWConfig, init_state

    rng = np.random.default_rng(2)
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    opt_state = init_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), "cosine", 50))
    toks, fe = _inputs(cfg, 4, 32, rng)
    losses = []
    for _ in range(8):
        params, opt_state, m = step(params, opt_state, toks, toks)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses


@pytest.mark.slow
def test_multi_step_decode_consistency():
    """Five decode steps == teacher-forced forward on the concatenation."""
    rng = np.random.default_rng(3)
    cfg = get_smoke_config("recurrentgemma-9b")  # hybrid: hardest cache mix
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, _ = _inputs(cfg, 1, 8, rng)
    _, cache = forward(params, cfg, toks, mode="prefill", cache_len=32)
    seq = [toks]
    outs = []
    cur = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 1)), jnp.int32)
    for _ in range(5):
        lg, cache = decode_step(params, cfg, cur, cache)
        outs.append(lg[:, 0])
        seq.append(cur)
        cur = jnp.argmax(lg[:, 0:1, :], axis=-1).astype(jnp.int32)
    full = forward(params, cfg, jnp.concatenate(seq, 1), mode="train")
    for t, o in enumerate(outs):
        ref = full[:, toks.shape[1] + t - 1 + 1]
        err = float(jnp.max(jnp.abs(o - ref)))
        assert err < 5e-4 * (float(jnp.max(jnp.abs(ref))) + 1), t


def test_shapes_for_honours_subquadratic_rule():
    assert len(shapes_for(get_config("recurrentgemma-9b"))) == 4
    assert len(shapes_for(get_config("xlstm-350m"))) == 4
    for a in ARCH_IDS:
        if a in ("recurrentgemma-9b", "xlstm-350m"):
            continue
        assert len(shapes_for(get_config(a))) == 3, a
        assert not sub_quadratic(get_config(a))


def test_full_configs_param_counts():
    """Full configs hit their advertised scale (abstract, no allocation)."""
    import math
    from repro.models.model import abstract_params

    expected = {  # rough total-param targets (weights incl. embeddings)
        "smollm-135m": (0.10e9, 0.2e9),
        "gemma-7b": (7e9, 10e9),
        "command-r-35b": (30e9, 40e9),
        "deepseek-v3-671b": (6.3e11, 7.2e11),
        "arctic-480b": (4.2e11, 5.2e11),
        "xlstm-350m": (0.25e9, 0.55e9),  # qkv internals unspecified in pool
        "whisper-large-v3": (1.2e9, 2.2e9),
        "recurrentgemma-9b": (8e9, 11e9),
        "minicpm-2b": (2.2e9, 3.3e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        n = sum(
            math.prod(l.shape) for l in jax.tree.leaves(abstract_params(cfg))
        )
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]B"


def test_moe_capacity_drops_are_bounded():
    """With cf=1.25 and balanced-ish routing, most tokens survive."""
    from repro.models.layers import init_moe, moe

    rng = np.random.default_rng(4)
    cfg = get_smoke_config("deepseek-v3-671b")
    p = init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 64, cfg.d_model)), jnp.float32)
    y = moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # shared expert guarantees non-zero output even for dropped tokens
    assert float(jnp.abs(y).mean()) > 0
