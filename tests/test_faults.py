"""Abrupt-failure fault plane (DESIGN.md §10): injected crashes and
message loss, the timeout suspicion/eviction detector, exact loss
accounting, and the `runtime.fault_tolerance` bridge.

The cross-backend contract under an armed fault plane:

  * a crashed peer is detected and evicted by its tree neighbors alone
    (no global view, no Alg. 2 notification from the victim), the tree
    re-heals, and every backend reconverges on the survivors' data;
  * the eviction *set* is backend-independent; eviction *timing* is
    cycle-exact on numpy (per-cycle detector) and dispatch-boundary
    granular on the device engines — the harness fault cells pin jax vs
    sharded to bit-identical timelines (tests/test_sharded.py runs the
    subprocess grid; `_diff_harness.FAULT_GRID` is the CI surface);
  * conservation stays exact with losses itemized:
    enqueued == retired + in_flight + dropped + lost_to_fault.
"""
import numpy as np
import pytest

from tests._hypothesis_shim import given, settings, st

from tests import _diff_harness as H

BACKENDS = ("numpy", "jax")


def _mk(backend, n=16, ring_seed=7, vote_period=3, **fkw):
    from repro.core.dht import Ring
    from repro.engine import make_engine
    from repro.engine.base import FaultConfig

    ring = Ring.random(n, 10, seed=ring_seed)
    votes = (np.arange(n) % vote_period == 0).astype(np.int64)
    eng = make_engine(backend, ring, votes, seed=0,
                      faults=FaultConfig(**fkw) if fkw else None)
    return eng, votes


def _truth(eng):
    v = np.asarray(eng.votes())
    return int(2 * v.sum() > eng.ring.n)


# ---------------------------------------------------------------------------
# configuration and API guards
# ---------------------------------------------------------------------------

def test_fault_config_validation():
    from repro.engine.base import FaultConfig

    FaultConfig()  # defaults are legal
    with pytest.raises(ValueError):
        FaultConfig(p_drop=1.5)
    with pytest.raises(ValueError):
        FaultConfig(p_delay=-0.1)
    with pytest.raises(ValueError):
        FaultConfig(suspect_after=0)
    with pytest.raises(ValueError):
        FaultConfig(evict_after=-1)
    with pytest.raises(ValueError):  # eviction before suspicion is nonsense
        FaultConfig(suspect_after=40, evict_after=40)


@pytest.mark.parametrize("backend", BACKENDS)
def test_crash_requires_armed_plane(backend):
    eng, _ = _mk(backend)
    with pytest.raises(RuntimeError):
        eng.crash(0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_crash_guards(backend):
    eng, _ = _mk(backend, suspect_after=10, evict_after=40)
    with pytest.raises(IndexError):
        eng.crash(99)
    eng.crash(3)
    with pytest.raises(ValueError):  # already dead
        eng.crash(3)
    assert eng.dead_mask()[3] and eng.dead_mask().sum() == 1


def test_batch_and_faults_do_not_compose():
    from repro.core.dht import Ring
    from repro.engine import make_engine
    from repro.engine.base import FaultConfig

    ring = Ring.random(16, 10, seed=0)
    votes = np.zeros((2, 16), np.int64)
    with pytest.raises(NotImplementedError):
        make_engine("jax", ring, votes, batch=2, faults=FaultConfig())


# ---------------------------------------------------------------------------
# crash -> suspicion -> eviction -> re-heal
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_crash_detected_and_evicted(backend):
    """The tree neighbors alone detect the silent crash, synthesize the
    Alg. 2 leave for exactly the dead address, and the survivors
    reconverge — with the loss ledger exact."""
    eng, _ = _mk(backend, n=16, suspect_after=10, evict_after=80, seed=1)
    eng.run_until_converged(truth=_truth(eng), max_cycles=5000)
    victim = 5
    dead_addr = int(eng.ring.addrs[victim])
    n0 = eng.ring.n
    eng.crash(victim)
    for _ in range(40):  # 40 * 16 cycles >> evict_after + probe RTT
        eng.step(16)
        if eng.evictions:
            break
    assert [a for _, a in eng.evictions] == [dead_addr]
    assert eng.ring.n == n0 - 1 and dead_addr not in set(
        int(a) for a in eng.ring.addrs)
    assert not eng.dead_mask().any()  # eviction cleared the dead slot
    eng.step(400)  # no false suspicion cascade afterwards
    assert len(eng.evictions) == 1
    res = eng.run_until_converged(truth=_truth(eng), max_cycles=20000)
    assert res["converged"] == 1.0
    if hasattr(eng, "check_conservation") and eng.backend == "jax":
        ledger = eng.check_conservation()
        assert ledger["dropped"] == 0 and ledger["lost_to_fault"] > 0
    else:
        eng.check_conservation()
        assert eng.lost_to_fault > 0  # the victim's in-flight rows died


@pytest.mark.parametrize("backend", BACKENDS)
def test_probe_only_detector_never_evicts(backend):
    """evict_after=0: the detector probes (repairing lost updates) but
    membership never changes, even with a dead peer in the ring."""
    eng, _ = _mk(backend, n=16, suspect_after=10, evict_after=0, seed=2)
    eng.run_until_converged(truth=_truth(eng), max_cycles=5000)
    n0 = eng.ring.n
    eng.crash(4)
    eng.step(300)
    assert eng.evictions == [] and eng.ring.n == n0
    assert eng.dead_mask().sum() == 1


# ---------------------------------------------------------------------------
# message loss / delay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_drop_delay_reconvergence_and_ledger(backend):
    """30% drop + 10% delay on the data plane: the suspicion probes
    repair the lost updates, the decision still converges, and every
    lost message is itemized (conservation exact, dropped == 0)."""
    eng, _ = _mk(backend, n=16, vote_period=3, p_drop=0.3, p_delay=0.1,
                 suspect_after=20, evict_after=0, seed=9)
    res = eng.run_until_converged(truth=_truth(eng), max_cycles=50000,
                                  stable_for=20)
    assert res["converged"] == 1.0
    assert eng.lost_to_fault > 0
    if eng.backend == "jax":
        ledger = eng.check_conservation()
        assert ledger["dropped"] == 0
        assert ledger["enqueued"] == (ledger["retired"] + ledger["live"]
                                      + ledger["lost_to_fault"])
    else:
        eng.check_conservation()


def test_drop_draws_are_mesh_invariant():
    """The drop/delay draws hash (global window index, t, seed), so the
    injected fault pattern is a property of the run, not the layout:
    jax and mesh=2 sharded lose the *same* messages at the same cycles."""
    eng1, _ = _mk("jax", n=16, vote_period=2, p_drop=0.25,
                  suspect_after=20, evict_after=0, seed=5)
    t1 = []
    for _ in range(30):
        eng1.step(5)
        t1.append((eng1.t, eng1.messages_sent, eng1.lost_to_fault,
                   eng1.in_flight))
    pytest.importorskip("jax")
    import jax

    if jax.local_device_count() < 1:  # pragma: no cover
        pytest.skip("no devices")
    eng2, _ = _mk("jax", n=16, vote_period=2, p_drop=0.25,
                  suspect_after=20, evict_after=0, seed=5)
    t2 = []
    for _ in range(30):
        eng2.step(5)
        t2.append((eng2.t, eng2.messages_sent, eng2.lost_to_fault,
                   eng2.in_flight))
    assert t1 == t2  # deterministic replay of the same fault pattern


# ---------------------------------------------------------------------------
# churn schedules with crashes
# ---------------------------------------------------------------------------

def test_crash_schedule_replays_on_both_backends():
    from repro.core.churn import random_schedule
    from repro.core.dht import Ring

    ring = Ring.random(24, 10, seed=2)
    sched = random_schedule(ring, 10, seed=5, p_leave=0.3, p_crash=0.25,
                            n_min=6, spacing=8, mass_join=3, range_fail=2)
    kinds = [op[0] for op in sched.ops]
    assert kinds.count("crash") >= 2 and kinds.count("join") >= 3
    assert len(sched.ops) == len(sched.gaps) == len(sched.snaps)
    counts, dead = {}, {}
    for backend in BACKENDS:
        eng, _ = _mk(backend, n=24, ring_seed=2, suspect_after=20,
                     evict_after=0, seed=3)
        sched.apply(eng)
        counts[backend] = eng.ring.n
        dead[backend] = int(eng.dead_mask().sum())
    assert counts["numpy"] == counts["jax"]
    assert dead["numpy"] == dead["jax"] == kinds.count("crash")


def test_schedule_drift_diagnostic_names_event():
    """An eviction mid-gap shrinks the engine ring under the schedule's
    feet; `apply` must say *which* event diverged instead of letting a
    later op fail with a bare IndexError."""
    from repro.core.churn import random_schedule
    from repro.core.dht import Ring

    ring = Ring.random(24, 10, seed=2)
    sched = random_schedule(ring, 8, seed=5, p_leave=0.0, p_crash=0.6,
                            n_min=6, spacing=120)
    assert any(op[0] == "crash" for op in sched.ops)
    eng, _ = _mk("numpy", n=24, ring_seed=2, suspect_after=5,
                 evict_after=30, seed=3)
    with pytest.raises(RuntimeError, match="diverged .* at event"):
        sched.apply(eng)


def test_crash_keeps_shadow_ring_address():
    """Delayed discovery: a crash op does not shrink the shadow ring —
    the snapshot still carries the dead address (the detector's job)."""
    from repro.core.churn import random_schedule
    from repro.core.dht import Ring

    ring = Ring.random(16, 10, seed=4)
    sched = random_schedule(ring, 6, seed=1, p_leave=0.0, p_crash=1.0,
                            n_min=4, spacing=5)
    for op, (r_after, _, a_im1, _) in zip(sched.ops, sched.snaps):
        if op[0] == "crash":
            assert a_im1 in set(int(a) for a in r_after.addrs)


# ---------------------------------------------------------------------------
# diff-harness fault cells (the quick in-process slice; the full grid
# incl. sharded trajectory parity is the CI job + tests/test_sharded.py)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_harness_crash_cell_numpy_vs_jax():
    sched = H.make_schedule("majority", 404, faults="crash")
    assert any(ev[0] == "crash" for ev in sched["events"])
    a = H.replay(sched, H.numpy_factory)
    b = H.replay(sched, H.jax_factory)
    assert len(a["evict_addrs"]) == 1
    H.assert_state_parity(a, b, "fault:crash")


@pytest.mark.slow
def test_harness_drop_cell_numpy_vs_jax():
    sched = H.make_schedule("majority", 606, faults="drop")
    a = H.replay(sched, H.numpy_factory)
    b = H.replay(sched, H.jax_factory)
    assert a["lost"] > 0 and b["lost"] > 0
    H.assert_state_parity(a, b, "fault:drop")


@pytest.mark.slow
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**20))
def test_fuzz_fault_cells_numpy_vs_jax(seed):
    """Hypothesis-driven fault schedules beyond the fixed FAULT_GRID
    (skips without hypothesis — the seeded grid keeps the floor)."""
    mode = "crash" if seed % 2 else "drop"
    sched = H.make_schedule("majority", seed, faults=mode)
    a = H.replay(sched, H.numpy_factory)
    b = H.replay(sched, H.jax_factory)
    H.assert_state_parity(a, b, f"fuzz:{mode}/seed={seed}")


# ---------------------------------------------------------------------------
# runtime.fault_tolerance: agent primitives + the engine bridge
# ---------------------------------------------------------------------------

def test_restart_policy_backoff_exhaustion():
    from repro.runtime.fault_tolerance import RestartPolicy

    p = RestartPolicy(max_restarts=3, backoff_s=1.0, backoff_mult=2.0)
    assert [p.next_delay() for _ in range(3)] == [1.0, 2.0, 4.0]
    assert p.next_delay() is None  # budget spent
    assert p.next_delay() is None  # and stays spent
    p.reset()
    assert p.next_delay() == 1.0


def test_restart_policy_zero_budget():
    from repro.runtime.fault_tolerance import RestartPolicy

    p = RestartPolicy(max_restarts=0)
    assert p.next_delay() is None


def test_straggler_tracker_median_edges():
    from repro.runtime.fault_tolerance import StragglerTracker

    tr = StragglerTracker(alpha=1.0, ratio=1.8)
    assert tr.stragglers() == []  # no data
    tr.record(0, 1.0)
    assert tr.stragglers() == []  # a single host has no peer median
    tr.record(1, 9.0)
    # two hosts: median = 5.0 and 9.0 sits exactly at ratio * median —
    # the median absorbs a pairwise outlier (strict > keeps it quiet)
    assert tr.stragglers() == []
    tr.record(2, 1.0)
    tr.record(3, 1.0)
    # now median is 1.0 and only the outlier exceeds ratio * median
    assert tr.stragglers() == [1]
    # all-equal fleet: nobody straggles at any ratio
    tr2 = StragglerTracker(alpha=1.0, ratio=1.0001)
    for h in range(4):
        tr2.record(h, 2.0)
    assert tr2.stragglers() == []


def test_straggler_tracker_ewma_forgives():
    from repro.runtime.fault_tolerance import StragglerTracker

    tr = StragglerTracker(alpha=0.5, ratio=1.5)
    for h in range(3):
        tr.record(h, 1.0)
    tr.record(2, 9.0)  # one bad step
    assert tr.stragglers() == [2]
    for _ in range(8):  # recovery decays the EWMA back under the bar
        tr.record(2, 1.0)
    assert tr.stragglers() == []


def test_engine_suspicion_bridge():
    """One detector serves both layers: engine `heard` stamps drive the
    agent HeartbeatMonitor on the cycle clock, and detector evictions
    consume the RestartPolicy budget."""
    from repro.runtime.fault_tolerance import (EngineSuspicionBridge,
                                               HeartbeatMonitor,
                                               RestartPolicy)

    eng, _ = _mk("numpy", n=16, suspect_after=10, evict_after=80, seed=1)
    eng.run_until_converged(truth=_truth(eng), max_cycles=5000)
    bridge = EngineSuspicionBridge(
        monitor=HeartbeatMonitor(timeout_s=40.0),  # cycles, via the bridge
        policy=RestartPolicy(max_restarts=1))
    assert bridge.sync(eng) == []
    assert bridge.suspects(eng) == []
    victim = 5
    dead_addr = int(eng.ring.addrs[victim])
    eng.crash(victim)
    eng.step(60)  # silent past the monitor timeout, before eviction
    bridge.sync(eng)
    assert dead_addr in bridge.suspects(eng)
    while not eng.evictions:
        eng.step(16)
    plans = bridge.sync(eng)
    assert plans == [(dead_addr, 1.0)]  # one restart planned, on budget
    assert dead_addr not in bridge.monitor.last_seen
    # a second eviction would exhaust the budget -> None delay
    assert bridge.policy.next_delay() is None


@pytest.mark.parametrize("backend", BACKENDS)
def test_last_heard_accessor(backend):
    eng, _ = _mk(backend, n=16, suspect_after=10, evict_after=0, seed=1)
    eng.step(30)
    lh = eng.last_heard()
    assert lh.shape == (eng.ring.n,)
    assert lh.max() > 0  # converging traffic stamped somebody
