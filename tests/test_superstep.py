"""Superstep engine: scan-fused execution and vmapped trial batching.

The PR 3 contract (DESIGN.md §Engine):
  * `step(K)` — one jitted while_loop dispatch — is BIT-identical to K
    single-cycle dispatches (state, messages, deferred, wheel contents);
  * the chunked `run_until_converged` (on-device convergence predicate,
    one host sync per chunk) reports exactly the cycles/messages the
    per-cycle reference loop would;
  * a vmapped B-trial batch matches B serial runs trial-for-trial;
  * the delivery wheel never loses rows (deferral, not drops) and its
    occupancy counters stay within capacity.
"""
import json

import numpy as np
import pytest

import jax

from repro.core.dht import Ring
from repro.engine import make_engine


def _votes(n, mu, rng):
    k = int(round(n * mu))
    v = np.zeros(n, np.int64)
    v[rng.choice(n, k, replace=False)] = 1
    return v


def _assert_states_equal(e1, e2):
    h1, h2 = jax.device_get(e1._st), jax.device_get(e2._st)
    for field, a, b in zip(h1._fields, h1, h2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"DeviceState.{field}")


# ---------------------------------------------------------------------------
# 1. superstep == K single steps, bit for bit
# ---------------------------------------------------------------------------

def test_superstep_bit_identical_to_single_steps():
    n = 192
    rng = np.random.default_rng(0)
    ring = Ring.random(n, 32, seed=0)
    votes = _votes(n, 0.4, rng)
    e1 = make_engine("jax", ring, votes, seed=3, kernel="ref")
    e2 = make_engine("jax", ring, votes, seed=3, kernel="ref")
    e1.step(41)
    for _ in range(41):
        e2.step(1)
    _assert_states_equal(e1, e2)
    assert (e1.t, e1.messages_sent, e1.deferred, e1.dropped) == \
           (e2.t, e2.messages_sent, e2.deferred, e2.dropped)


def test_superstep_bit_identical_under_budget_pressure():
    """Slip/leftover/spill paths active (deferred > 0) and still
    bit-identical across dispatch granularities."""
    n = 160
    rng = np.random.default_rng(1)
    ring = Ring.random(n, 32, seed=1)
    votes = _votes(n, 0.45, rng)
    e1 = make_engine("jax", ring, votes, seed=4, kernel="ref", work_budget=24)
    e2 = make_engine("jax", ring, votes, seed=4, kernel="ref", work_budget=24)
    e1.step(60)
    for k in (7, 13, 1, 25, 14):
        e2.step(k)
    assert e1.deferred > 0  # the budget did bind
    _assert_states_equal(e1, e2)


# ---------------------------------------------------------------------------
# 2. chunked convergence loop == per-cycle reference loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stable_for", [1, 5])
def test_chunked_convergence_matches_percycle_loop(stable_for):
    n = 128
    rng = np.random.default_rng(2)
    ring = Ring.random(n, 32, seed=2)
    votes = _votes(n, 0.3, rng)
    fast = make_engine("jax", ring, votes, seed=5, kernel="ref", chunk=64)
    res = fast.run_until_converged(truth=0, max_cycles=20_000,
                                   stable_for=stable_for)
    assert res["converged"] == 1.0

    # reference: same engine driven check-then-step one cycle at a time
    ref = make_engine("jax", ring, votes, seed=5, kernel="ref")
    stable = 0
    for _ in range(20_000):
        if (ref.outputs() == 0).all():
            stable += 1
            if stable >= stable_for:
                break
        else:
            stable = 0
        ref.step(1)
    assert res["cycles"] == ref.t
    assert res["messages"] == ref.messages_sent
    _assert_states_equal(fast, ref)


def test_chunked_convergence_respects_max_cycles():
    n = 64
    rng = np.random.default_rng(3)
    ring = Ring.random(n, 32, seed=3)
    votes = _votes(n, 0.3, rng)
    eng = make_engine("jax", ring, votes, seed=6, kernel="ref", chunk=32)
    res = eng.run_until_converged(truth=1, max_cycles=100)  # wrong truth
    assert res["converged"] == 0.0
    assert eng.t <= 100


# ---------------------------------------------------------------------------
# 3. vmapped batch == serial runs, trial for trial
# ---------------------------------------------------------------------------

def test_batched_matches_serial_trial_for_trial():
    B, n = 4, 160
    rng = np.random.default_rng(4)
    ring = Ring.random(n, 32, seed=4)
    votes = np.stack([_votes(n, mu, rng) for mu in (0.25, 0.45, 0.55, 0.7)])
    truths = (2 * votes.sum(1) >= n).astype(np.int64)

    bat = make_engine("jax", ring, votes, seed=11, batch=B, kernel="ref")
    res_b = bat.run_until_converged(truths)
    outs_b = bat.outputs()
    for b in range(B):
        ser = make_engine("jax", ring, votes[b], seed=11 + b, kernel="ref")
        res_s = ser.run_until_converged(int(truths[b]))
        assert res_s == res_b[b], f"trial {b}"
        np.testing.assert_array_equal(ser.outputs(), outs_b[b])
    assert all(r["converged"] == 1.0 for r in res_b)
    assert (bat.dropped == 0).all()


def test_batched_step_matches_serial():
    B, n = 3, 96
    rng = np.random.default_rng(5)
    rings = [Ring.random(n, 32, seed=20 + b) for b in range(B)]
    votes = np.stack([_votes(n, 0.4, rng) for _ in range(B)])
    bat = make_engine("jax", rings, votes, seed=30, batch=B, kernel="ref")
    bat.step(50)
    for b in range(B):
        ser = make_engine("jax", rings[b], votes[b], seed=30 + b, kernel="ref")
        ser.step(50)
        assert ser.messages_sent == int(bat.messages_sent[b])
        np.testing.assert_array_equal(ser.outputs(), bat.outputs()[b])


def test_batched_numpy_wrapper_and_set_votes():
    B, n = 2, 96
    rng = np.random.default_rng(6)
    ring = Ring.random(n, 32, seed=6)
    votes = np.stack([_votes(n, 0.3, rng) for _ in range(B)])
    jb = make_engine("jax", ring, votes, seed=40, batch=B, kernel="ref")
    nb = make_engine("numpy", ring, votes, seed=40, batch=B)
    for r in nb.run_until_converged(0) + jb.run_until_converged(0):
        assert r["converged"] == 1.0
    # ragged batched vote flip (idx -1 = no-op rows)
    idx = np.full((B, 3), -1)
    idx[0, :2] = [1, 2]
    idx[1, :1] = [5]
    val = np.ones((B, 3), np.int64)
    jb.set_votes(idx, val)
    nb.set_votes(idx, val)
    np.testing.assert_array_equal(jb.votes(), nb.votes())
    jb.step(400)
    nb.step(400)
    np.testing.assert_array_equal(jb.outputs(), nb.outputs())


def test_batched_api_guards():
    ring = Ring.random(32, 32, seed=7)
    votes = np.zeros((2, 32), np.int64)
    with pytest.raises(ValueError):  # votes must be (B, n)
        make_engine("jax", ring, votes[0], batch=2)
    with pytest.raises(ValueError):  # mismatched ring count
        from repro.engine.batched import BatchedJaxEngine

        BatchedJaxEngine([ring], votes)
    with pytest.raises(ValueError):  # mismatched (n, d)
        make_engine("jax", [ring, Ring.random(16, 32, seed=8)], votes, batch=2)


# ---------------------------------------------------------------------------
# 4. delivery-wheel invariants
# ---------------------------------------------------------------------------

def test_wheel_occupancy_and_no_silent_loss():
    n = 300
    rng = np.random.default_rng(8)
    ring = Ring.random(n, 32, seed=8)
    votes = _votes(n, 0.45, rng)
    eng = make_engine("jax", ring, votes, seed=9, kernel="ref", work_budget=64)
    for _ in range(12):
        eng.step(25)
        assert 0 <= eng.in_flight <= eng.capacity
        wcnt = np.asarray(eng._st.wcnt)
        acnt = np.asarray(eng._st.acnt)
        assert (wcnt >= 0).all() and (wcnt <= eng.slot_cap).all()
        assert (acnt >= 0).all() and (acnt <= 64).all()
    assert eng.deferred > 0   # the tiny budget did bind
    assert eng.dropped == 0   # but nothing was lost
    res = eng.run_until_converged(truth=0, max_cycles=30_000)
    assert res["converged"] == 1.0 and res["invalid"] == 0.0


# ---------------------------------------------------------------------------
# 5. bench smoke + regression checker (the CI perf gate machinery)
# ---------------------------------------------------------------------------

@pytest.mark.bench
def test_engine_bench_smoke(tmp_path):
    """Smoke-sized engine benchmark (the `--smoke` CI configuration):
    records both backends, preserves a baseline, and the regression
    checker consumes its own output."""
    from benchmarks import engine_bench

    out = tmp_path / "BENCH_engine.json"
    lines = []
    engine_bench.run(lines.append, **engine_bench.SMOKE, out_path=str(out))
    data = json.loads(out.read_text())
    assert data["rows"][0]["jax"]["dropped"] == 0
    assert data["rows"][0]["jax"]["cycles_per_sec"] > 0
    # second run demotes the first rows to the baseline and reports speedup
    engine_bench.run(lines.append, **engine_bench.SMOKE, out_path=str(out))
    data2 = json.loads(out.read_text())
    assert "baseline" in data2 and "jax_over_baseline" in data2["rows"][0]
    # regression checker: equal perf passes, an absurd committed value fails
    assert engine_bench.check_regression(lines.append, out_path=str(out),
                                         max_n=256)
    data2["rows"][0]["jax"]["cycles_per_sec"] = 1e9
    out.write_text(json.dumps(data2))
    assert not engine_bench.check_regression(lines.append, out_path=str(out),
                                             max_n=256)


@pytest.mark.bench
def test_engine_bench_warm_reuse():
    """A repeat `bench_backend` call with an identical config must hit
    the in-process engine cache: no reconstruction/re-jit (the cold
    ~2.5s setup_s), just a state-snapshot restore."""
    from benchmarks import engine_bench

    engine_bench._ENGINE_CACHE.clear()
    cold = engine_bench.bench_backend("jax", 256, cycles=5, reps=1)
    warm = engine_bench.bench_backend("jax", 256, cycles=5, reps=1)
    assert "engine_reused" not in cold
    assert warm.get("engine_reused") is True
    assert warm["setup_s"] < max(cold["setup_s"], 0.05)
    # both records carry the deferral-rate counter next to deferred
    for rec in (cold, warm):
        assert rec["deferral_rate"] == pytest.approx(
            rec["deferred"] / max(rec["messages"], 1), abs=1e-4)
    # identical measured work either way
    assert warm["messages"] == cold["messages"]
    assert warm["deferred"] == cold["deferred"]


@pytest.mark.bench
@pytest.mark.slow
def test_run_smoke_xla_cache_warm(tmp_path):
    """`benchmarks.run --only engine --smoke` twice from a fresh
    working dir: the first run populates the persistent XLA cache, the
    second must fully hit it (no new cache entries) and set up faster."""
    import os
    import re
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = repo + os.pathsep + os.path.join(repo, "src")
    env["JAX_PLATFORMS"] = "cpu"
    # the run must use its own cache under tmp_path (run.py respects an
    # inherited cache dir, e.g. on CI)
    env.pop("JAX_COMPILATION_CACHE_DIR", None)

    def smoke():
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only", "engine",
             "--smoke"],
            capture_output=True, text=True, env=env, cwd=str(tmp_path),
            timeout=1200,
        )
        assert r.returncode == 0, r.stdout + r.stderr
        m = re.search(r"engine,n=\d+,backend=jax,.*setup_s=([\d.]+)",
                      r.stdout)
        assert m, r.stdout
        return float(m.group(1))

    cache = tmp_path / "results" / ".jax_cache"
    cold_setup = smoke()
    entries = set(os.listdir(cache))
    assert entries, "first --smoke left no persistent XLA cache entries"
    warm_setup = smoke()
    assert set(os.listdir(cache)) == entries, \
        "second --smoke missed the persistent XLA cache (new entries)"
    assert warm_setup < max(cold_setup, 0.1)


@pytest.mark.bench
def test_sweep_smoke(tmp_path):
    from benchmarks import sweep

    out = tmp_path / "BENCH_sweep.json"
    lines = []
    sweep.run(lines.append, **sweep.SMOKE, margins=(0.3, 0.7),
              out_path=str(out))
    data = json.loads(out.read_text())
    assert data["batch"] == 4
    assert len(data["rows"]) == 2
    for row in data["rows"]:
        assert row["lsp_converge_rate"] == 1.0


@pytest.mark.bench
def test_sweep_problem_smoke(tmp_path):
    """`--problem {mean,l2}` grids merge under `problems.<name>` while
    the majority rows stay at the top level."""
    from benchmarks import sweep

    out = tmp_path / "BENCH_sweep.json"
    lines = []
    sweep.run(lines.append, **sweep.SMOKE, margins=(0.3, 0.7),
              out_path=str(out))
    for problem in ("mean", "l2"):
        sweep.run(lines.append, **sweep.SMOKE, offsets=(-0.4, 0.4),
                  problem=problem, out_path=str(out))
    data = json.loads(out.read_text())
    assert len(data["rows"]) == 2  # majority rows survived the merges
    for problem in ("mean", "l2"):
        grid = data["problems"][problem]
        assert len(grid["rows"]) == 2
        for row in grid["rows"]:
            assert row["converge_rate"] == 1.0
            assert row["msgs_per_peer"] > 0
