"""Fused Alg. 3 kernel: equality with the jnp oracle AND the numpy
simulator state machine — three implementations, one semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.majority import MajorityState
from repro.kernels.majority_step.ops import majority_step
from repro.kernels.majority_step.ref import majority_step_reference


@pytest.mark.parametrize("n", [8, 17, 1000, 5000])
@pytest.mark.parametrize("seed", [0, 3])
def test_kernel_vs_ref_vs_simulator(n, seed):
    rng = np.random.default_rng(seed)
    io = jnp.asarray(rng.integers(0, 50, (n, 3)), jnp.int32)
    it = io + jnp.asarray(rng.integers(0, 50, (n, 3)), jnp.int32)
    oo = jnp.asarray(rng.integers(0, 50, (n, 3)), jnp.int32)
    ot = oo + jnp.asarray(rng.integers(0, 50, (n, 3)), jnp.int32)
    x = jnp.asarray(rng.integers(0, 2, (n,)), jnp.int32)
    k = majority_step(io, it, oo, ot, x)
    r = majority_step_reference(io, it, oo, ot, x)
    for a, b in zip(k, r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    st = MajorityState(n, np.asarray(x, np.int64))
    st.X_in[:, :, 0] = np.asarray(io)
    st.X_in[:, :, 1] = np.asarray(it)
    st.X_out[:, :, 0] = np.asarray(oo)
    st.X_out[:, :, 1] = np.asarray(ot)
    np.testing.assert_array_equal(np.asarray(k[0]), st.violations())
    np.testing.assert_array_equal(np.asarray(k[1]), st.outputs())


def test_send_payload_resolves_violation():
    """After Send(v) (X_out <- K - X_in), the direction's violation clears."""
    rng = np.random.default_rng(7)
    n = 500
    io = jnp.asarray(rng.integers(0, 20, (n, 3)), jnp.int32)
    it = io + jnp.asarray(rng.integers(0, 20, (n, 3)), jnp.int32)
    oo = jnp.asarray(rng.integers(0, 20, (n, 3)), jnp.int32)
    ot = oo + jnp.asarray(rng.integers(0, 20, (n, 3)), jnp.int32)
    x = jnp.asarray(rng.integers(0, 2, (n,)), jnp.int32)
    viol, out, po, pt = majority_step(io, it, oo, ot, x)
    # apply Send on violated directions
    oo2 = jnp.where(viol, po, oo)
    ot2 = jnp.where(viol, pt, ot)
    viol2, *_ = majority_step(io, it, oo2, ot2, x)
    assert not bool((viol & viol2).any()), "Send did not resolve violation"
