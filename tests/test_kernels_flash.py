"""Flash attention: Pallas kernel (interpret) + XLA-scan path vs oracle,
swept over shapes/dtypes/masks; gradients against the oracle VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ops import decode_attention, flash_attention
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.flash_attention.xla_ref import flash_attention_xla

rng = np.random.default_rng(0)
mk = lambda s, dt=jnp.float32: jnp.asarray(rng.standard_normal(s), dt)

SWEEP = [
    # b, hq, hkv, sq, skv, dh, dhv, causal, window, dtype
    (1, 4, 4, 128, 128, 64, 64, True, None, jnp.float32),
    (2, 8, 2, 128, 256, 64, 64, True, None, jnp.float32),
    (1, 4, 1, 256, 256, 128, 128, True, 128, jnp.float32),
    (2, 4, 4, 128, 128, 32, 32, False, None, jnp.bfloat16),
    (1, 2, 2, 384, 384, 64, 64, True, 256, jnp.float32),
    (1, 4, 2, 128, 128, 192, 128, True, None, jnp.float32),  # MLA dims
]


@pytest.mark.parametrize("case", SWEEP)
def test_pallas_kernel_matches_oracle(case):
    b, hq, hkv, sq, skv, dh, dhv, causal, window, dt = case
    q, k, v = mk((b, hq, sq, dh), dt), mk((b, hkv, skv, dh), dt), mk((b, hkv, skv, dhv), dt)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              interpret=True)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


@pytest.mark.parametrize("case", SWEEP)
def test_xla_flash_matches_oracle(case):
    b, hq, hkv, sq, skv, dh, dhv, causal, window, dt = case
    q, k, v = mk((b, hq, sq, dh), dt), mk((b, hkv, skv, dh), dt), mk((b, hkv, skv, dhv), dt)
    out = flash_attention_xla(q, k, v, causal, window)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_xla_flash_grads_match_oracle():
    q, k, v = mk((1, 4, 128, 64)), mk((1, 2, 128, 64)), mk((1, 2, 128, 64))

    def loss_k(q, k, v):
        return (flash_attention_xla(q, k, v, True, None) ** 2).sum()

    def loss_r(q, k, v):
        return (mha_reference(q, k, v, causal=True) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-4)


def test_kv_len_masks_padding():
    q, k, v = mk((2, 4, 128, 64)), mk((2, 4, 192, 64)), mk((2, 4, 192, 64))
    o1 = flash_attention_xla(q, k, v, False, None, None, 0, 150)
    o2 = mha_reference(q, k[:, :, :150], v[:, :, :150], causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


def test_dispatch_wrapper_differentiable():
    q, k, v = mk((1, 2, 128, 32)), mk((1, 2, 128, 32)), mk((1, 2, 128, 32))
    g = jax.grad(lambda q: flash_attention(q, k, v).sum())(q)
    assert bool(jnp.isfinite(g).all())


def test_decode_attention_matches_sliced_reference():
    q1 = mk((2, 8, 1, 64))
    kc, vc = mk((2, 2, 256, 64)), mk((2, 2, 256, 64))
    lens = jnp.array([100, 256], jnp.int32)
    o = decode_attention(q1, kc, vc, length=lens)
    for bi, L in enumerate([100, 256]):
        r = mha_reference(q1[bi:bi + 1], kc[bi:bi + 1, :, :L],
                          vc[bi:bi + 1, :, :L], causal=False)
        np.testing.assert_allclose(np.asarray(o[bi:bi + 1]), np.asarray(r),
                                   atol=3e-5)
