"""Optional-hypothesis shim for the property tests.

`hypothesis` is not part of the baked image and cannot be installed
offline. Importing it at module scope used to *error* the whole test
collection (pytest aborts on collection errors, taking every other test
down with it). This shim keeps the property tests importable: when
hypothesis is present it re-exports the real `given`/`settings`/
`strategies`; when absent it substitutes decorators that turn each
property test into an individual skip, leaving the non-property tests in
the same module running normally.
"""
from __future__ import annotations

import functools
import inspect

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*a, **k):
                pytest.skip("hypothesis not installed")

            # present a zero-arg signature so pytest does not mistake the
            # strategy parameters (reachable via __wrapped__) for fixtures
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategy:
        """Inert stand-in: every strategy constructor returns None."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategy()
