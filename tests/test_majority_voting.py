"""Alg. 3 + LiMoSense system behaviour (paper §4.2 claims, scaled down)."""
import numpy as np
import pytest

from repro.core.dht import Ring
from repro.core.limosense import LiMoSenseSimulator
from repro.core.majority import MajoritySimulator


def _votes(n, mu, rng):
    k = int(round(n * mu))
    v = np.zeros(n, np.int64)
    v[rng.choice(n, k, replace=False)] = 1
    return v


@pytest.mark.slow
@pytest.mark.parametrize("mu,truth", [(0.3, 0), (0.7, 1), (0.45, 0), (0.55, 1)])
def test_local_majority_converges_to_truth(mu, truth):
    rng = np.random.default_rng(0)
    ring = Ring.random(400, 48, seed=0)
    sim = MajoritySimulator(ring, _votes(400, mu, rng), seed=1)
    res = sim.run_until_converged(truth=truth, max_cycles=50_000)
    assert res["converged"] == 1.0


@pytest.mark.slow
def test_vote_flip_reconverges():
    """Paper §4.2.1: mu_pre < 1/2 < mu_post transition."""
    rng = np.random.default_rng(1)
    ring = Ring.random(300, 48, seed=1)
    sim = MajoritySimulator(ring, _votes(300, 0.3, rng), seed=2)
    r1 = sim.run_until_converged(truth=0)
    assert r1["converged"] == 1.0
    new = _votes(300, 0.7, rng)
    chg = np.nonzero(new != sim.state.x)[0]
    sim.set_votes(chg, new[chg])
    r2 = sim.run_until_converged(truth=1)
    assert r2["converged"] == 1.0


@pytest.mark.slow
def test_local_beats_gossip_on_messages():
    """The paper's headline: local thresholding uses a fraction of the
    messages gossip needs for the same task."""
    rng = np.random.default_rng(2)
    n = 1000
    ring = Ring.random(n, 48, seed=2)
    votes = _votes(n, 0.3, rng)
    loc = MajoritySimulator(ring, votes, seed=3)
    r_loc = loc.run_until_converged(truth=0)
    gos = LiMoSenseSimulator(ring, votes, seed=3)
    r_gos = gos.run_until_converged(truth=0)
    assert r_loc["converged"] and r_gos["converged"]
    assert r_loc["messages"] < 0.5 * r_gos["messages"], (
        r_loc["messages"], r_gos["messages"])


def test_all_same_votes_silent():
    """Unanimous input: no violations, (almost) no messages."""
    ring = Ring.random(200, 48, seed=4)
    sim = MajoritySimulator(ring, np.ones(200, np.int64), seed=5)
    for _ in range(50):
        sim.step()
    assert sim.messages_sent == 0
    assert (sim.state.outputs() == 1).all()


def test_knowledge_conservation():
    """In-flight + held counts never exceed the true total of votes
    (messages carry differences; the knowledge sums stay consistent)."""
    rng = np.random.default_rng(5)
    ring = Ring.random(150, 48, seed=6)
    votes = _votes(150, 0.4, rng)
    sim = MajoritySimulator(ring, votes, seed=7)
    sim.run_until_converged(truth=0, max_cycles=20_000)
    k = sim.state.knowledge()
    # after quiescence every peer's knowledge must reflect the global tally
    # direction-exact equality holds only at the root in general; check sign
    assert (sim.state.outputs() == 0).all()


def test_alert_triggers_resync():
    """Alg. 2 alerts reach BOTH endpoints of each affected edge (paper
    §3.1: 'once both peers send and accept those messages, A reflects an
    agreement'); a both-sided spurious alert must leave the answer intact."""
    from repro.core import addressing as A

    ring = Ring.random(100, 48, seed=8)
    rng = np.random.default_rng(8)
    votes = _votes(100, 0.2, rng)
    sim = MajoritySimulator(ring, votes, seed=9)
    sim.run_until_converged(truth=0)
    m0 = sim.messages_sent
    up_n, cw_n, ccw_n = A.tree_neighbors_reference(ring.addrs, ring.d)
    peers, dirs = [], []
    for i in (3, 4):
        if up_n[i] >= 0:
            j = int(up_n[i])
            peers += [i, j]
            # reciprocal direction at the parent: i sits in j's CW or CCW
            recip = A.CW if cw_n[j] == i else A.CCW
            dirs += [A.UP, recip]
    sim.alert(np.array(peers), np.array(dirs))
    for _ in range(400):
        sim.step()
    assert sim.messages_sent > m0  # alerts force fresh exchanges
    assert (sim.state.outputs() == 0).all()  # and the answer survives
