import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.simplefilter("ignore")
import dataclasses
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs.registry import get_smoke_config
from repro.models.layers import init_moe, moe
from repro.distributed.moe_ep import set_moe_mesh

cfg0 = get_smoke_config("deepseek-v3-671b")
# 8 experts over model axis 4 -> 2 experts/shard; generous capacity = no drop
cfg_g = dataclasses.replace(cfg0, moe=dataclasses.replace(cfg0.moe, capacity_factor=8.0, impl="gather"))
cfg_e = dataclasses.replace(cfg0, moe=dataclasses.replace(cfg0.moe, capacity_factor=8.0, impl="ep_a2a"))
mesh = jax.make_mesh((2, 4), ("data", "model"))
p = init_moe(jax.random.PRNGKey(1), cfg_g, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 16, cfg0.d_model)), jnp.float32)

set_moe_mesh(mesh, ("data",), "model")
with mesh:
    xg = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    y_g = jax.jit(lambda p, x: moe(p, x, cfg_g))(p, xg)
    y_e = jax.jit(lambda p, x: moe(p, x, cfg_e))(p, xg)
    err = float(jnp.max(jnp.abs(y_g - y_e)))
    print("fwd err:", err, "scale:", float(jnp.max(jnp.abs(y_g))))
    assert err < 1e-4 * (float(jnp.max(jnp.abs(y_g))) + 1)
    g_g = jax.jit(jax.grad(lambda p, x: (moe(p, x, cfg_g)**2).sum()))(p, xg)
    g_e = jax.jit(jax.grad(lambda p, x: (moe(p, x, cfg_e)**2).sum()))(p, xg)
    for k in ("router", "w_gate", "w_up", "w_down"):
        e = float(jnp.max(jnp.abs(g_g[k] - g_e[k])))
        s = float(jnp.max(jnp.abs(g_g[k]))) + 1e-9
        print(f"grad {k}: relerr {e/s:.2e}")
        assert e / s < 1e-3, k
print("MOE_EP_OK")
