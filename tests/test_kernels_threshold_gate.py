"""Threshold compression kernel: sweep + hypothesis invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.kernels.threshold_gate.ops import threshold_gate
from repro.kernels.threshold_gate.ref import threshold_gate_reference


@pytest.mark.parametrize("shape", [(64,), (1000,), (128, 257), (3, 5, 7), (70000,)])
@pytest.mark.parametrize("tau", [0.0, 0.1, 0.5, 2.0])
def test_kernel_matches_reference(shape, tau):
    rng = np.random.default_rng(hash((shape, tau)) % 2**31)
    g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    r = jnp.asarray(rng.standard_normal(shape) * 0.3, jnp.float32)
    s1, nr1, c1 = threshold_gate(g, r, tau)
    s2, nr2, c2 = threshold_gate_reference(g, r, jnp.float32(tau))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(nr1), np.asarray(nr2))
    assert int(c1) == int(c2)


@given(
    # subnormals excluded: XLA flushes them to zero (FTZ) while numpy keeps
    # them, so `send != 0` legitimately disagrees at |x| < 2^-126 — found
    # by hypothesis, documented here rather than papered over with a tol
    st.lists(st.floats(-10, 10, width=32, allow_subnormal=False),
             min_size=1, max_size=200),
    st.floats(0, 5, width=32, allow_subnormal=False),
)
@settings(max_examples=100, deadline=None)
def test_error_feedback_conserves_mass(vals, tau):
    """send + new_residual == grad + residual exactly (nothing lost)."""
    g = jnp.asarray(vals, jnp.float32)
    r = jnp.asarray(np.roll(vals, 1), jnp.float32)
    s, nr, c = threshold_gate(g, r, tau)
    np.testing.assert_allclose(
        np.asarray(s) + np.asarray(nr), np.asarray(g) + np.asarray(r),
        atol=1e-6,
    )
    # everything sent is >= tau in magnitude; everything kept is < tau
    sent = np.asarray(s)
    acc = np.asarray(g) + np.asarray(r)
    mask = np.abs(acc) >= tau
    assert int(c) == int(mask.sum())
    np.testing.assert_array_equal(sent != 0, mask & (acc != 0))


def test_tau_zero_sends_everything():
    g = jnp.asarray([1.0, -2.0, 0.5], jnp.float32)
    r = jnp.zeros(3, jnp.float32)
    s, nr, c = threshold_gate(g, r, 0.0)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(g))
    np.testing.assert_array_equal(np.asarray(nr), np.zeros(3))
    assert int(c) == 3
