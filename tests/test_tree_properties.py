"""Fig 4.1 regression gate: the tree-properties benchmark is persisted
(results/BENCH_tree.json) and BOUNDED, not just printed.

Two layers: the committed JSON must satisfy the paper's envelopes (a
stale or hand-edited file fails here), and a small fresh recompute must
satisfy them too (a regression in the addressing/tree layer fails even
if nobody re-ran the full benchmark). Bounds live next to the benchmark
(`benchmarks.tree_properties.check_bounds`) so the writer and the gate
can never drift apart.
"""
import json
import os

from benchmarks import tree_properties as TP

COMMITTED = os.path.join(os.path.dirname(__file__), "..", "results",
                         "BENCH_tree.json")


def test_committed_tree_bench_satisfies_fig41_bounds():
    with open(COMMITTED) as f:
        results = json.load(f)
    # the full-size committed run must cover the paper's figure range
    assert {r["n"] for r in results["depth"]} >= {10_000, 100_000, 1_000_000}
    bad = TP.check_bounds(results)
    assert not bad, "; ".join(bad)
    # Fig 4.1a headline: full levels track floor(log2 n) - 2 at scale
    for r in results["depth"]:
        if r["n"] >= 10_000:
            assert r["full_levels"] >= int(r["log2n"]) - 2, r


def test_fresh_recompute_satisfies_fig41_bounds():
    """Small fresh run through the same gate (seconds, not minutes)."""
    lines = []
    TP.run(lines.append, out_path=os.devnull, **TP.SMOKE)
    assert any(line.startswith("tree_depth") for line in lines)


def test_gate_actually_detects_violations():
    assert TP.full_levels_floor(10_000) == 13 - 2
    assert TP.full_levels_floor(4096) == 12 - 3
    bad = TP.check_bounds({
        "depth": [{"n": 10_000, "full_levels": 1, "max_depth": 25,
                   "log2n": 13.3}],
        "stretch": [{"n": 10_000, "mean_tree_hops": 5.0}],
        "hop_distance": [{"n": 10_000,
                          "symmetric": {"mean": 6.0, "p_le_2": 0.2},
                          "chord": {"mean": 6.0}}],
    })
    assert len(bad) == 6, bad
