"""Property tests for the paper's address algebra (paper §2, Appendix A)."""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import addressing as A
from repro.core.dht import Ring

D = 16
MASK = A.mask_of(D)

addr = st.integers(min_value=1, max_value=MASK)


@given(addr)
@settings(max_examples=200, deadline=None)
def test_up_inverts_children(a):
    a = np.uint64(a)
    if not bool(A.is_leaf(a)):
        assert int(A.up(A.cw(a, D), D)) == int(a)
        assert int(A.up(A.ccw(a, D), D)) == int(a)


@given(addr)
@settings(max_examples=200, deadline=None)
def test_up_chain_reaches_root(a):
    cur = np.uint64(a)
    for _ in range(D + 1):
        if int(cur) == 0:
            return
        nxt = A.up(cur, D)
        # parent is strictly more aligned
        assert int(A.lowbit(nxt)) > int(A.lowbit(cur)) or int(nxt) == 0
        cur = nxt
    assert int(cur) == 0


@given(addr, addr)
@settings(max_examples=300, deadline=None)
def test_subtree_membership_vs_up_walk(x, y):
    """in_subtree(x, y) iff repeatedly applying UP to y reaches x."""
    xs, ys = np.uint64(x), np.uint64(y)
    cur, reaches = ys, False
    for _ in range(D + 2):
        if int(cur) == int(xs):
            reaches = True
            break
        if int(cur) == 0:
            break
        cur = A.up(cur, D)
    if int(xs) == 0:
        reaches = True  # root's subtree is everything
    assert bool(A.in_subtree(xs, ys, D)) == reaches


@given(addr, addr)
@settings(max_examples=300, deadline=None)
def test_cw_ccw_subtrees_partition(x, y):
    xs, ys = np.uint64(x), np.uint64(y)
    if int(xs) == int(ys):
        return
    inside = bool(A.in_subtree(xs, ys, D))
    cw = bool(A.in_cw_subtree(xs, ys, D))
    ccw = bool(A.in_ccw_subtree(xs, ys, D))
    assert (cw + ccw) == (1 if inside else 0)


@given(st.integers(0, MASK), st.integers(0, MASK))
@settings(max_examples=300, deadline=None)
def test_position_most_aligned_in_segment(prev, self_):
    """Lemma: the position is the unique most-aligned address in (prev, self]."""
    if prev == self_:
        return
    p = int(A.position_from_segment(np.uint64(prev), np.uint64(self_), D))
    if prev >= self_:
        assert p == 0  # wrapped segment owns address 0
        return
    assert prev < p <= self_
    tz = int(A.trailing_zeros(np.uint64(p), D))
    for cand in range(prev + 1, self_ + 1):
        if cand != p:
            assert int(A.trailing_zeros(np.uint64(cand), D)) <= tz


def test_jax_matches_numpy():
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    a32 = rng.integers(1, 2**20, 500, dtype=np.uint64).astype(np.uint32)
    jj = jnp.asarray(a32)
    d = 20
    np.testing.assert_array_equal(np.asarray(A.up(jj, d)), A.up(a32, d))
    np.testing.assert_array_equal(np.asarray(A.cw(jj, d)), A.cw(a32, d))
    np.testing.assert_array_equal(np.asarray(A.ccw(jj, d)), A.ccw(a32, d))
    np.testing.assert_array_equal(
        np.asarray(A.lowbit(jj)), A.lowbit(a32)
    )
    np.testing.assert_array_equal(
        np.asarray(A.in_subtree(jj, jj[::-1].copy(), d)),
        A.in_subtree(a32, a32[::-1], d),
    )


def test_ring_positions_unique_and_in_segment():
    ring = Ring.random(5000, 48, seed=3)
    pos = ring.positions()
    assert np.unique(pos).size == ring.n
    prev = ring.prev
    inseg = (pos <= ring.addrs) & (pos > prev)
    inseg[np.argmin(ring.addrs)] = True  # wrapped root segment
    assert inseg.all()


def test_lemma1_subtree_segments_continuous():
    """Lemma 1: peers in any subtree own a continuous address range."""
    ring = Ring.random(400, 32, seed=1)
    pos = ring.positions()
    order = np.argsort(ring.addrs)
    for i in range(0, ring.n, 37):
        root = pos[i]
        member = A.in_subtree(np.uint64(root), pos, 32)
        idx = np.sort(np.nonzero(member)[0])
        if idx.size > 1:
            assert (np.diff(idx) == 1).all(), "subtree peers not contiguous"


def test_tree_depth_bound():
    """Paper §4.1: no peer deeper than log2(N) + 6 (we allow +7 slack)."""
    ring = Ring.random(20_000, 64, seed=2)
    up_n, _, _ = A.tree_neighbors_reference(ring.addrs, 64)
    depth = np.zeros(ring.n, np.int64)
    # BFS from root
    from collections import defaultdict, deque

    ch = defaultdict(list)
    for i, u in enumerate(up_n):
        if u >= 0:
            ch[int(u)].append(i)
    root = int(np.argmin(ring.addrs))
    q = deque([root])
    seen = 1
    while q:
        x = q.popleft()
        for c in ch[x]:
            depth[c] = depth[x] + 1
            q.append(c)
            seen += 1
    assert seen == ring.n, "tree disconnected"
    assert depth.max() <= np.log2(ring.n) + 7
