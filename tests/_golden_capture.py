"""Capture golden majority-engine trajectories from the CURRENT code.

Run once against the pre-refactor engine to freeze its behaviour:

    PYTHONPATH=src python tests/_golden_capture.py

The frozen grid (tests/golden_majority.json) is what
tests/test_problems.py compares the `ThresholdProblem`-routed Majority
path against — cycles, message counts and full output vectors must stay
bit-identical through the problem-layer refactor and beyond.
"""
import hashlib
import json
import os

import numpy as np

from repro.core.dht import Ring
from repro.engine import make_engine

GRID = [
    # (n, mu, ring_seed, eng_seed, backend, kernel)
    (48, 0.3, 0, 1, "numpy", None),
    (48, 0.3, 0, 1, "jax", "ref"),
    (96, 0.55, 2, 3, "numpy", None),
    (96, 0.55, 2, 3, "jax", "ref"),
    (160, 0.45, 4, 5, "numpy", None),
    (160, 0.45, 4, 5, "jax", "ref"),
]

BATCH = {"n": 96, "mus": (0.25, 0.6), "ring_seed": 7, "eng_seed": 11}


def _votes(n, mu, rng):
    v = np.zeros(n, np.int64)
    v[rng.choice(n, int(round(n * mu)), replace=False)] = 1
    return v


def run_cell(n, mu, ring_seed, eng_seed, backend, kernel):
    rng = np.random.default_rng(ring_seed + 100)
    ring = Ring.random(n, 32, seed=ring_seed)
    votes = _votes(n, mu, rng)
    kw = {"kernel": kernel} if kernel else {}
    eng = make_engine(backend, ring, votes, seed=eng_seed, **kw)
    truth = int(2 * votes.sum() >= n)
    res = eng.run_until_converged(truth=truth, max_cycles=20_000)
    # vote flip exercises set_votes + reconvergence
    new = _votes(n, 1.0 - mu, rng)
    chg = np.nonzero(new != eng.votes())[0]
    eng.set_votes(chg, new[chg])
    truth2 = int(2 * new.sum() >= n)
    res2 = eng.run_until_converged(truth=truth2, max_cycles=20_000)
    # churn: one join + one leave, then reconverge
    free = np.setdiff1d(
        np.arange(1, 1 << 16, dtype=np.uint64), ring.addrs % (1 << 16)
    )
    eng.join(int(free[3]), vote=1)
    eng.leave(0)
    v = eng.votes()
    truth3 = int(2 * v.sum() >= v.size)
    res3 = eng.run_until_converged(truth=truth3, max_cycles=20_000)
    return {
        "cell": [n, mu, ring_seed, eng_seed, backend, kernel or ""],
        "stages": [
            {"cycles": int(res["cycles"]), "messages": int(res["messages"]),
             "converged": res["converged"]},
            {"cycles": int(res2["cycles"]), "messages": int(res2["messages"]),
             "converged": res2["converged"]},
            {"cycles": int(res3["cycles"]), "messages": int(res3["messages"]),
             "converged": res3["converged"]},
        ],
        "outputs_sha": hashlib.sha256(
            eng.outputs().astype(np.int64).tobytes()).hexdigest(),
        "votes_sha": hashlib.sha256(
            eng.votes().astype(np.int64).tobytes()).hexdigest(),
    }


def run_batch():
    n = BATCH["n"]
    rng = np.random.default_rng(BATCH["ring_seed"] + 100)
    ring = Ring.random(n, 32, seed=BATCH["ring_seed"])
    votes = np.stack([_votes(n, mu, rng) for mu in BATCH["mus"]])
    truths = (2 * votes.sum(1) >= n).astype(np.int64)
    eng = make_engine("jax", ring, votes, seed=BATCH["eng_seed"],
                      batch=votes.shape[0], kernel="ref")
    res = eng.run_until_converged(truths)
    return {
        "cell": [n, list(BATCH["mus"]), BATCH["ring_seed"], BATCH["eng_seed"]],
        "results": [{"cycles": int(r["cycles"]),
                     "messages": int(r["messages"]),
                     "converged": r["converged"]} for r in res],
        "outputs_sha": hashlib.sha256(
            eng.outputs().astype(np.int64).tobytes()).hexdigest(),
    }


def main():
    out = {
        "comment": "pre-refactor majority engine trajectories (PR 3 HEAD)",
        "cells": [run_cell(*c) for c in GRID],
        "batched": run_batch(),
    }
    path = os.path.join(os.path.dirname(__file__), "golden_majority.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    for c in out["cells"]:
        print(c["cell"], c["stages"], c["outputs_sha"][:12])


if __name__ == "__main__":
    main()
