"""Capture golden engine trajectories from the CURRENT code.

Run once to freeze behaviour:

    PYTHONPATH=src python tests/_golden_capture.py

Two frozen grids live in tests/golden_majority.json:

  * ``cells`` / ``batched`` — the majority engine. The numpy cells are
    the PR 3 HEAD trajectories (pre-problem-layer) and have never
    moved; the jax cells were re-anchored at the owner-partitioned
    wheel (PR 7 — lane-relative delay ordinals legitimately re-time
    deliveries; outputs and vote hashes reproduced the old capture
    exactly). tests/test_problems.py replays them: cycles, message
    counts and full output vectors must stay bit-identical through
    every later refactor. Re-running this script must reproduce them
    EXACTLY — a changed cell means the engine's trajectory drifted and
    the capture must not be committed.
  * ``problems`` — `MeanMonitor` and `L2Thresh` trajectories (numpy:
    PR 5 HEAD; jax: PR 7 re-anchor), so every SHIPPED problem is
    pinned across versions, not just majority: initial convergence, a
    full-width data flip, then churn, on both backends.
"""
import hashlib
import json
import os

import numpy as np

from repro.core.dht import Ring
from repro.engine import L2Thresh, MeanMonitor, make_engine

GRID = [
    # (n, mu, ring_seed, eng_seed, backend, kernel)
    (48, 0.3, 0, 1, "numpy", None),
    (48, 0.3, 0, 1, "jax", "ref"),
    (96, 0.55, 2, 3, "numpy", None),
    (96, 0.55, 2, 3, "jax", "ref"),
    (160, 0.45, 4, 5, "numpy", None),
    (160, 0.45, 4, 5, "jax", "ref"),
]

BATCH = {"n": 96, "mus": (0.25, 0.6), "ring_seed": 7, "eng_seed": 11}

PROBLEM_GRID = [
    # (problem, n, ring_seed, eng_seed, backend)
    ["mean", 96, 6, 7, "numpy"],
    ["mean", 96, 6, 7, "jax"],
    ["l2", 96, 8, 9, "numpy"],
    ["l2", 96, 8, 9, "jax"],
]


def _votes(n, mu, rng):
    v = np.zeros(n, np.int64)
    v[rng.choice(n, int(round(n * mu)), replace=False)] = 1
    return v


def run_cell(n, mu, ring_seed, eng_seed, backend, kernel):
    rng = np.random.default_rng(ring_seed + 100)
    ring = Ring.random(n, 32, seed=ring_seed)
    votes = _votes(n, mu, rng)
    kw = {"kernel": kernel} if kernel else {}
    eng = make_engine(backend, ring, votes, seed=eng_seed, **kw)
    truth = int(2 * votes.sum() >= n)
    res = eng.run_until_converged(truth=truth, max_cycles=20_000)
    # vote flip exercises set_votes + reconvergence
    new = _votes(n, 1.0 - mu, rng)
    chg = np.nonzero(new != eng.votes())[0]
    eng.set_votes(chg, new[chg])
    truth2 = int(2 * new.sum() >= n)
    res2 = eng.run_until_converged(truth=truth2, max_cycles=20_000)
    # churn: one join + one leave, then reconverge
    free = np.setdiff1d(
        np.arange(1, 1 << 16, dtype=np.uint64), ring.addrs % (1 << 16)
    )
    eng.join(int(free[3]), vote=1)
    eng.leave(0)
    v = eng.votes()
    truth3 = int(2 * v.sum() >= v.size)
    res3 = eng.run_until_converged(truth=truth3, max_cycles=20_000)
    return {
        "cell": [n, mu, ring_seed, eng_seed, backend, kernel or ""],
        "stages": [
            {"cycles": int(res["cycles"]), "messages": int(res["messages"]),
             "converged": res["converged"]},
            {"cycles": int(res2["cycles"]), "messages": int(res2["messages"]),
             "converged": res2["converged"]},
            {"cycles": int(res3["cycles"]), "messages": int(res3["messages"]),
             "converged": res3["converged"]},
        ],
        "outputs_sha": hashlib.sha256(
            eng.outputs().astype(np.int64).tobytes()).hexdigest(),
        "votes_sha": hashlib.sha256(
            eng.votes().astype(np.int64).tobytes()).hexdigest(),
    }


def _problem_instance(name):
    """Fixed-parameter instances — the golden values pin THESE."""
    return (MeanMonitor(tau=0.0, scale=256) if name == "mean"
            else L2Thresh(tau=1.0, dim=2))


def _problem_data(name, n, rng, phase):
    """Raw data plane for (problem, phase): phase 0 decides one way,
    phase 1 flips the global decision."""
    if name == "mean":
        off = -0.6 if phase == 0 else 0.6
        return rng.normal(off, 0.8, size=n)
    # l2: mean outside / inside the tau=1 ball, but with enough spread
    # that many INDIVIDUAL peers start on the wrong side — the protocol
    # must actually move knowledge (a tight cluster converges in 0
    # cycles and pins nothing)
    r = 1.3 if phase == 0 else 0.45
    c = np.array([0.6, -0.8]) * r
    return rng.normal(c, 0.9, size=(n, 2))


def run_problem_cell(cell):
    """One mean/l2 golden cell: converge, full-width data flip, churn —
    shared verbatim by the capture (writes) and the test (compares)."""
    name, n, ring_seed, eng_seed, backend = cell
    problem = _problem_instance(name)
    rng = np.random.default_rng(ring_seed + 200)
    ring = Ring.random(n, 32, seed=ring_seed)
    data = _problem_data(name, n, rng, 0)
    eng = make_engine(backend, ring, data, seed=eng_seed, problem=problem)
    stages = [eng.run_until_converged(
        truth=problem.global_output(eng.data()), max_cycles=20_000)]
    # full-width data flip: every peer's data changes, decision flips
    eng.set_votes(np.arange(n), _problem_data(name, n, rng, 1))
    stages.append(eng.run_until_converged(
        truth=problem.global_output(eng.data()), max_cycles=20_000))
    # churn: one join + one leave, then reconverge
    free = np.setdiff1d(
        np.arange(1, 1 << 16, dtype=np.uint64), ring.addrs % (1 << 16))
    eng.join(int(free[3]), vote=_problem_data(name, 1, rng, 1)[0])
    eng.leave(0)
    stages.append(eng.run_until_converged(
        truth=problem.global_output(eng.data()), max_cycles=20_000))
    return {
        "cell": list(cell),
        "stages": [
            {"cycles": int(s["cycles"]), "messages": int(s["messages"]),
             "converged": s["converged"]} for s in stages
        ],
        "outputs_sha": hashlib.sha256(
            eng.outputs().astype(np.int64).tobytes()).hexdigest(),
        "data_sha": hashlib.sha256(
            eng.data().astype(np.int64).tobytes()).hexdigest(),
    }


def run_batch():
    n = BATCH["n"]
    rng = np.random.default_rng(BATCH["ring_seed"] + 100)
    ring = Ring.random(n, 32, seed=BATCH["ring_seed"])
    votes = np.stack([_votes(n, mu, rng) for mu in BATCH["mus"]])
    truths = (2 * votes.sum(1) >= n).astype(np.int64)
    eng = make_engine("jax", ring, votes, seed=BATCH["eng_seed"],
                      batch=votes.shape[0], kernel="ref")
    res = eng.run_until_converged(truths)
    return {
        "cell": [n, list(BATCH["mus"]), BATCH["ring_seed"], BATCH["eng_seed"]],
        "results": [{"cycles": int(r["cycles"]),
                     "messages": int(r["messages"]),
                     "converged": r["converged"]} for r in res],
        "outputs_sha": hashlib.sha256(
            eng.outputs().astype(np.int64).tobytes()).hexdigest(),
    }


def main():
    path = os.path.join(os.path.dirname(__file__), "golden_majority.json")
    out = {
        "comment": "majority + mean/l2 engine trajectories (numpy: "
                   "PR 3/5 HEAD; jax: PR 7 owner-partitioned-wheel "
                   "re-anchor, output hashes unchanged)",
        "cells": [run_cell(*c) for c in GRID],
        "batched": run_batch(),
        "problems": [run_problem_cell(c) for c in PROBLEM_GRID],
    }
    # a capture that moves a frozen cell is a drifted engine, not new
    # goldens — refuse to overwrite silently. Every grid already in the
    # committed file (majority, batched, AND the problem cells) must be
    # reproduced exactly; only genuinely new cells may appear.
    if os.path.exists(path):
        old = json.load(open(path))
        for key in ("cells", "problems"):
            olds = old.get(key, [])
            assert len(out[key]) >= len(olds), f"{key}: grid shrank"
            for got, want in zip(out[key], olds):
                assert got == want, (
                    f"{key} golden drift!\n got: {got!r}\nwant: {want!r}")
        assert out["batched"] == old.get("batched"), "batched golden drift!"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    for c in out["cells"] + out["problems"]:
        print(c["cell"], c["stages"], c["outputs_sha"][:12])


if __name__ == "__main__":
    main()
