"""Tests for the measurement machinery itself (analysis.hlo / roofline) —
wrong meters are worse than no meters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import (
    collective_bytes, flops_and_bytes, loop_scales, xla_cost,
)


def test_scan_flops_scale_with_trip_count():
    """The reason analysis.hlo exists: XLA cost_analysis counts while
    bodies once; our walker must scale by trip count exactly."""

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.zeros((256, 256))
    ws = jnp.zeros((10, 256, 256))
    comp = jax.jit(scanned).lower(x, ws).compile()
    xla = xla_cost(comp)["flops"]
    ours = flops_and_bytes(comp.as_text())["flops"]
    want = 10 * 2 * 256 ** 3
    assert xla == pytest.approx(want / 10)  # the documented XLA behaviour
    assert ours == pytest.approx(want)


def test_nested_scan_scales_multiply():
    def inner(c, w):
        def body(c2, w2):
            return c2 @ w2, None

        y, _ = jax.lax.scan(body, c, w)
        return y, None

    def outer(x, ws):
        y, _ = jax.lax.scan(inner, x, ws)
        return y

    x = jnp.zeros((64, 64))
    ws = jnp.zeros((3, 4, 64, 64))  # 3 outer x 4 inner = 12 matmuls
    txt = jax.jit(outer).lower(x, ws).compile().as_text()
    fb = flops_and_bytes(txt)
    assert fb["flops"] == pytest.approx(12 * 2 * 64 ** 3)
    # the inner body is a >=2-deep nested scope -> kernel-scope attribution
    assert fb["kernel_scope_flops"] == pytest.approx(12 * 2 * 64 ** 3)


def test_collective_bytes_sees_psum():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import warnings; warnings.simplefilter("ignore")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.tree_collectives import shard_map
        from repro.analysis.hlo import collective_bytes
        mesh = jax.make_mesh((4,), ("d",))
        f = shard_map(lambda v: jax.lax.psum(v, "d"), mesh=mesh,
                      in_specs=P("d"), out_specs=P(), check_vma=False)
        txt = jax.jit(f).lower(jnp.zeros((64, 128), jnp.float32)).compile().as_text()
        cb = collective_bytes(txt)
        want = 16 * 128 * 4  # per-device shard bytes
        assert abs(cb.get("all-reduce", 0) - want) < want, cb
        print("CB_OK", cb)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300)
    assert "CB_OK" in r.stdout, r.stdout + r.stderr


def test_roofline_rows_sane_on_recorded_cells():
    import glob
    import json

    from repro.analysis.roofline import roofline_row

    recs = [json.load(open(f)) for f in
            sorted(glob.glob("results/dryrun/*__sp.json"))]
    if not recs:
        pytest.skip("no dry-run records present")
    n_rows = 0
    for r in recs:
        row = roofline_row(r)
        if row is None:
            continue
        n_rows += 1
        for k in ("t_compute_s", "t_mem_kernel_s", "t_collective_s"):
            assert row[k] >= 0
        assert row["dominant"] in ("compute", "memory", "collective")
        assert 0 <= row["roofline_mfu"] <= 1
        assert row["useful_ratio"] > 0
    assert n_rows >= 30  # 32 OK cells expected


def test_active_params_moe_counts_topk_only():
    from repro.analysis.roofline import active_params
    from repro.configs.registry import get_config

    dense = active_params(get_config("gemma-7b"))
    assert 7e9 < dense < 10e9
    ds = get_config("deepseek-v3-671b")
    act = active_params(ds)
    # DeepSeek-V3: ~37B active of 671B total
    assert 2.5e10 < act < 5.5e10, act
