"""Optimizer, schedules, data pipeline, checkpointing, runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as C
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import schedules
from repro.optim.adamw import AdamWConfig, apply_update, init_state
from repro.runtime.elastic import Membership
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor, RestartPolicy, StragglerTracker,
)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, state, _ = apply_update(params, g, state, cfg, jnp.float32(1.0))
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = init_state(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    _, _, m = apply_update(params, g, state, cfg, jnp.float32(1.0))
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_schedules_shape():
    for kind in ("cosine", "linear", "wsd"):
        f = schedules.get(kind)
        v0 = float(f(0, 1000))
        vm = float(f(500, 1000))
        ve = float(f(999, 1000))
        assert 0 <= v0 <= 1 and 0 <= ve <= 1
        assert vm > ve or kind == "linear"
    # WSD: flat in the middle
    w = schedules.wsd
    assert abs(float(w(300, 1000)) - float(w(600, 1000))) < 1e-6
    assert float(w(995, 1000)) < 0.5


def test_data_deterministic_and_restorable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
    a = SyntheticLM(cfg)
    batches = [a.next_batch() for _ in range(5)]
    b = SyntheticLM(cfg)
    b.load_state_dict({"step": 3})
    t3, y3 = b.next_batch()
    np.testing.assert_array_equal(t3, batches[3][0])
    np.testing.assert_array_equal(y3, batches[3][1])
    # shards partition the batch deterministically
    s0 = SyntheticLM(DataConfig(1000, 32, 4, seed=7, n_shards=2, shard=0))
    s1 = SyntheticLM(DataConfig(1000, 32, 4, seed=7, n_shards=2, shard=1))
    t0, _ = s0.next_batch()
    t1, _ = s1.next_batch()
    assert t0.shape == (2, 32) and t1.shape == (2, 32)
    assert not np.array_equal(t0, t1)
    # targets are next-token shifted
    t, y = batches[0]
    np.testing.assert_array_equal(y[:, :-1], t[:, 1:])
    assert (y[:, -1] == -1).all()


def test_checkpoint_roundtrip_resave_rotation(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"p": jnp.arange(6.0).reshape(2, 3), "c": jnp.zeros((), jnp.int32)}
    for step in (10, 20, 30, 40):
        C.save(d, step, tree, {"data": {"step": step}})
    assert C.latest_step(d) == 40
    out, extra = C.restore(d, 30, tree)
    assert extra["data"]["step"] == 30
    # re-save same step (failure-recovery replay) must not corrupt
    C.save(d, 40, tree, {"data": {"step": 40}})
    out, extra = C.restore(d, 40, tree)
    np.testing.assert_array_equal(np.asarray(out["p"]), np.arange(6.0).reshape(2, 3))
    # manager rotation
    mgr = C.CheckpointManager(d, keep=2)
    mgr.save_async(50, tree, {"data": {"step": 50}})
    mgr._drain()
    import time

    for _ in range(50):
        if C.latest_step(d) == 50:
            break
        time.sleep(0.05)
    assert C.latest_step(d) == 50


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ck2")
    C.save(d, 1, {"p": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        C.restore(d, 1, {"p": jnp.zeros((3, 3))})


def test_heartbeat_and_straggler():
    hb = HeartbeatMonitor(timeout_s=10)
    hb.beat(0, now=100.0)
    hb.beat(1, now=105.0)
    assert hb.dead(now=112.0) == [0]
    st = StragglerTracker(ratio=1.5)
    for h, t in [(0, 1.0), (1, 1.1), (2, 1.0), (3, 5.0)]:
        for _ in range(5):
            st.record(h, t)
    assert st.stragglers() == [3]
    rp = RestartPolicy(max_restarts=2, backoff_s=1.0)
    assert rp.next_delay() == 1.0
    assert rp.next_delay() == 2.0
    assert rp.next_delay() is None


def test_elastic_membership_blast_radius():
    """Lemma 5 at the cluster level: a host leave re-wires <= 6 hosts."""
    m = Membership(host_ids=list(range(64)))
    up, cw, ccw = m.tree_neighbors()
    assert (up >= 0).sum() == 63  # everyone but the root has a parent
    for rank in (0, 17, 63):
        affected = m.affected_by_leave(rank)
        assert len(affected) <= 6, (rank, affected)
    assert len(m.affected_by_join()) <= 6
