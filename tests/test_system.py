"""End-to-end behaviour: train loop with failure recovery, serving loop,
threshold-sync trainer, and a dry-run cell compile (subprocess)."""
import os
import subprocess
import sys

import numpy as np
import pytest


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                          text=True, env=env, timeout=timeout)


@pytest.mark.slow
def test_train_smoke_with_failure_recovery(tmp_path):
    r = _run([
        "repro.launch.train", "--arch", "smollm-135m", "--smoke",
        "--steps", "25", "--batch", "4", "--seq-len", "64",
        "--log-every", "5", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "10", "--fail-at", "13",
    ])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "failure at step 13" in r.stdout
    assert "step=20" in r.stdout  # resumed past the failure


@pytest.mark.slow
def test_threshold_sync_trainer():
    r = _run([
        "repro.launch.train", "--arch", "smollm-135m", "--smoke",
        "--sync", "threshold", "--pods", "2", "--steps", "15",
        "--batch", "4", "--seq-len", "32", "--tau", "0.001",
        "--max-inner", "8", "--log-every", "5",
    ])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "total outer syncs" in r.stdout
    # bounded staleness forces at least one sync within 15 steps
    syncs = int(r.stdout.split("total outer syncs: ")[1].split("/")[0])
    assert syncs >= 1


@pytest.mark.slow
def test_serve_smoke():
    r = _run([
        "repro.launch.serve", "--arch", "smollm-135m", "--smoke",
        "--requests", "4", "--slots", "2", "--max-new", "4",
        "--prompt-len", "8", "--cache-len", "32",
    ])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "served 4 requests" in r.stdout


@pytest.mark.slow
def test_dryrun_cell_compiles():
    """One full-scale cell through the real dry-run path (512 devices)."""
    r = _run([
        "repro.launch.dryrun", "--arch", "xlstm-350m", "--shape",
        "long_500k", "--out", "/tmp/dryrun_test",
    ])
    assert r.returncode == 0, r.stderr[-2000:]
    assert '"status": "OK"' in r.stdout
