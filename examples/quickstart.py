"""Quickstart: train a small LM for a few steps on CPU, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.model import decode_step, forward, init_params
from repro.optim.adamw import AdamWConfig, init_state


def main():
    cfg = get_smoke_config("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_state(params)
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=64, global_batch=8))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), "cosine", 40))

    print("== training ==")
    for i in range(40):
        tokens, targets = data.next_batch()
        params, opt_state, m = step(params, opt_state,
                                    jnp.asarray(tokens), jnp.asarray(targets))
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")

    print("== greedy decoding ==")
    prompt = jnp.asarray(np.arange(8)[None, :], jnp.int32)
    logits, cache = forward(params, cfg, prompt, mode="prefill", cache_len=32)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    for _ in range(10):
        logits, cache = decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("generated tokens:", out)


if __name__ == "__main__":
    main()
