"""Fault tolerance + elastic membership demo:
  1. train with periodic checkpoints, inject a failure, auto-resume;
  2. show the paper's Lemma-5 blast radius for cluster membership changes;
  3. run a *live* churn drill: the majority-voting engine keeps
     converging while hosts join and leave mid-run (Alg. 2 upcalls);
  4. re-shard the checkpoint onto a smaller 'cluster'.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as C
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig, init_state
from repro.runtime.elastic import Membership, churn_drill, remesh_plan


def main():
    cfg = get_smoke_config("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_state(params)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), "cosine", 30))
    ckdir = tempfile.mkdtemp(prefix="repro_ck_")
    mgr = C.CheckpointManager(ckdir, keep=2)

    print("== training with checkpoints ==")
    i = 0
    crashed = False
    while i < 30:
        try:
            if i == 17 and not crashed:
                crashed = True
                raise RuntimeError("simulated host failure at step 17")
            tokens, targets = data.next_batch()
            params, opt_state, m = step(params, opt_state,
                                        jnp.asarray(tokens),
                                        jnp.asarray(targets))
            if i % 10 == 0:
                mgr.save_async(i, {"params": params, "opt": opt_state},
                               {"data": data.state_dict()})
                print(f"step {i:3d} loss {float(m['loss']):.4f}  [checkpoint]")
            i += 1
        except RuntimeError as e:
            print(f"!! {e} — restoring latest checkpoint")
            mgr._drain()
            got = mgr.restore_latest({"params": params, "opt": opt_state})
            i, tree, extra = got
            params, opt_state = tree["params"], tree["opt"]
            data.load_state_dict(extra["data"])
            print(f"resumed from step {i}")

    print("\n== elastic membership (paper Alg. 2 at cluster level) ==")
    m = Membership(host_ids=list(range(32)))
    print("host 13 dies -> control-tree re-wires only hosts:",
          m.affected_by_leave(13))
    print("a host joins   -> alerted hosts:", m.affected_by_join())
    print("re-mesh plan 32->31 hosts:", remesh_plan(32, 31, dp=8, tp=4)["new"])

    print("\n== live churn drill (engine under Alg. 2 join/leave) ==")
    drill = churn_drill(hosts=32, events=6, backend="numpy", seed=0)
    print(f"{drill['joins']} joins + {drill['leaves']} leaves -> "
          f"{drill['hosts_end']} hosts; reconverged in "
          f"{drill['reconverge_cycles']} cycles "
          f"({drill['reconverge_messages']} messages, "
          f"converged={drill['converged']:.0f})")

    print("\n== elastic re-shard via checkpoint ==")
    got = mgr.restore_latest({"params": params, "opt": opt_state})
    print(f"checkpoint step {got[0]} restored onto the 'new cluster' "
          f"(device_put with the new mesh's shardings on real hardware)")


if __name__ == "__main__":
    main()
