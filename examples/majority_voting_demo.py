"""The paper, end to end: a DHT ring, the binary routing tree, a vote flip,
and the local-thresholding vs gossip message bill.

Runs on either cycle engine (`repro.engine`): the numpy reference or the
device-resident jax backend (one jitted program per cycle, Pallas
majority kernel on TPU). ``--problem`` swaps the threshold decision rule
(the pluggable `ThresholdProblem` layer, DESIGN.md §Problems): majority
is the paper's Alg. 3; ``mean`` monitors whether the network-wide mean
sits above a threshold; ``l2`` thresholds the norm of a 2-D mean vector.

    PYTHONPATH=src python examples/majority_voting_demo.py
    PYTHONPATH=src python examples/majority_voting_demo.py --backend jax
    PYTHONPATH=src python examples/majority_voting_demo.py --problem mean
    PYTHONPATH=src python examples/majority_voting_demo.py --problem l2 --backend jax

``--mesh K`` runs the mesh-sharded engine over K local devices
(bit-identical trajectory — DESIGN.md §Sharding); on CPU, spawn virtual
devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python examples/majority_voting_demo.py --backend jax --mesh 8
"""
import argparse

import numpy as np

from repro.core import addressing as A
from repro.core.dht import Ring
from repro.core.limosense import LiMoSenseSimulator
from repro.engine import get_problem, make_engine


def run_problem_demo(args):
    """Mean / L2 monitoring: converge, shift the data across the
    threshold, reconverge — same engine, different decision rule."""
    n = args.peers
    rng = np.random.default_rng(0)
    ring = Ring.random(n, 32, seed=0)
    if args.problem == "mean":
        prob = get_problem("mean", tau=0.5)
        lo, hi = rng.normal(0.1, 1.0, n), rng.normal(1.1, 1.0, n)
        desc = f"mean(x) >= {prob.tau}"
    else:
        prob = get_problem("l2", tau=1.0, dim=2)
        lo = rng.normal([0.2, -0.1], 0.5, (n, 2))
        hi = rng.normal([0.9, 0.8], 0.5, (n, 2))
        desc = f"||mean vec|| >= {prob.tau} (2-D, {prob.U.shape[0]} tangent half-spaces)"
    print(f"== {n} peers, problem: {prob!r} — {desc}, "
          f"backend: {args.backend} ==")
    t_lo = prob.global_output(prob.init_state(lo))
    eng = make_engine(args.backend, ring, lo, seed=1, problem=prob,
                      **args.engine_kw)
    r = eng.run_until_converged(truth=t_lo)
    print(f"below-threshold data: decision {t_lo}, converged in "
          f"{r['cycles']} cycles, {r['messages']/n:.2f} messages/peer")
    eng.set_votes(np.arange(n), hi)  # raw units: set_votes quantizes
    t_hi = prob.global_output(prob.init_state(hi))
    r2 = eng.run_until_converged(truth=t_hi)
    print(f"data shifted across tau: decision {t_hi}, re-converged in "
          f"{r2['cycles'] - r['cycles']} cycles, "
          f"{r2['messages']/n:.2f} messages/peer")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"))
    ap.add_argument("--peers", type=int, default=2000)
    ap.add_argument("--problem", default="majority",
                    choices=("majority", "mean", "l2"),
                    help="threshold decision rule (DESIGN.md §Problems)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard the jax engine over this many local "
                         "devices (0 = unsharded; DESIGN.md §Sharding)")
    args = ap.parse_args()
    args.engine_kw = {"mesh": args.mesh} if args.mesh else {}
    if args.mesh and args.backend != "jax":
        ap.error("--mesh needs --backend jax")

    if args.problem != "majority":
        return run_problem_demo(args)

    n = args.peers
    rng = np.random.default_rng(0)
    # the device engine routes on uint32 addresses (d <= 32)
    d = 48 if args.backend == "numpy" else 32
    ring = Ring.random(n, d, seed=0)
    pos = ring.positions()
    up_n, cw_n, ccw_n = A.tree_neighbors_reference(ring.addrs, ring.d)
    print(f"== {n} peers on a {d}-bit ring, engine backend: {args.backend} ==")
    root = int(np.argmin(ring.addrs))
    print(f"root peer: #{root} (owns address 0)")
    i = 42
    print(f"peer #{i}: position {int(pos[i]):012x}, "
          f"UP -> #{up_n[i]}, CW -> #{cw_n[i]}, CCW -> #{ccw_n[i]}")

    votes = np.zeros(n, np.int64)
    votes[rng.choice(n, int(n * 0.35), replace=False)] = 1
    print("\n== local majority voting (Alg. 3) ==")
    sim = make_engine(args.backend, ring, votes, seed=1,
                      **args.engine_kw)
    r = sim.run_until_converged(truth=0)
    print(f"converged in {r['cycles']} cycles, "
          f"{r['messages']/n:.2f} messages/peer")

    print("flipping the electorate: 35% ones -> 65% ones ...")
    new = np.zeros(n, np.int64)
    new[rng.choice(n, int(n * 0.65), replace=False)] = 1
    chg = np.nonzero(new != sim.votes())[0]
    sim.set_votes(chg, new[chg])
    r2 = sim.run_until_converged(truth=1)
    print(f"re-converged in {r2['cycles'] - r['cycles']} cycles, "
          f"{r2['messages']/n:.2f} messages/peer")
    total_local = r["messages"] + r2["messages"]

    print("\n== LiMoSense gossip on the same task ==")
    gos = LiMoSenseSimulator(ring, votes, seed=1)
    g = gos.run_until_converged(truth=0)
    gos.set_votes(np.arange(n), new)
    g2 = gos.run_until_converged(truth=1)
    print(f"gossip: {(g['messages'] + g2['messages'])/n:.2f} messages/peer "
          f"(local thresholding used "
          f"{(g['messages']+g2['messages'])/max(total_local,1):.1f}x fewer)")


if __name__ == "__main__":
    main()
