"""The paper, end to end: a DHT ring, the binary routing tree, a vote flip,
and the local-thresholding vs gossip message bill.

    PYTHONPATH=src python examples/majority_voting_demo.py
"""
import numpy as np

from repro.core import addressing as A
from repro.core.dht import Ring
from repro.core.limosense import LiMoSenseSimulator
from repro.core.majority import MajoritySimulator


def main():
    n = 2000
    rng = np.random.default_rng(0)
    ring = Ring.random(n, 48, seed=0)
    pos = ring.positions()
    up_n, cw_n, ccw_n = A.tree_neighbors_reference(ring.addrs, ring.d)
    print(f"== {n} peers on a 48-bit ring ==")
    root = int(np.argmin(ring.addrs))
    print(f"root peer: #{root} (owns address 0)")
    i = 42
    print(f"peer #{i}: position {int(pos[i]):012x}, "
          f"UP -> #{up_n[i]}, CW -> #{cw_n[i]}, CCW -> #{ccw_n[i]}")

    votes = np.zeros(n, np.int64)
    votes[rng.choice(n, int(n * 0.35), replace=False)] = 1
    print("\n== local majority voting (Alg. 3) ==")
    sim = MajoritySimulator(ring, votes, seed=1)
    r = sim.run_until_converged(truth=0)
    print(f"converged in {r['cycles']} cycles, "
          f"{r['messages']/n:.2f} messages/peer")

    print("flipping the electorate: 35% ones -> 65% ones ...")
    new = np.zeros(n, np.int64)
    new[rng.choice(n, int(n * 0.65), replace=False)] = 1
    chg = np.nonzero(new != sim.state.x)[0]
    sim.set_votes(chg, new[chg])
    r2 = sim.run_until_converged(truth=1)
    print(f"re-converged in {r2['cycles'] - r['cycles']} cycles, "
          f"{r2['messages']/n:.2f} messages/peer")

    print("\n== LiMoSense gossip on the same task ==")
    gos = LiMoSenseSimulator(ring, votes, seed=1)
    g = gos.run_until_converged(truth=0)
    gos.set_votes(np.arange(n), new)
    g2 = gos.run_until_converged(truth=1)
    print(f"gossip: {(g['messages'] + g2['messages'])/n:.2f} messages/peer "
          f"(local thresholding used "
          f"{(g['messages']+g2['messages'])/max(r2['messages'],1):.1f}x fewer)")


if __name__ == "__main__":
    main()
