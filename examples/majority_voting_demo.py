"""The paper, end to end: a DHT ring, the binary routing tree, a vote flip,
and the local-thresholding vs gossip message bill.

Runs on either cycle engine (`repro.engine`): the numpy reference or the
device-resident jax backend (one jitted program per cycle, Pallas
majority kernel on TPU).

    PYTHONPATH=src python examples/majority_voting_demo.py
    PYTHONPATH=src python examples/majority_voting_demo.py --backend jax
"""
import argparse

import numpy as np

from repro.core import addressing as A
from repro.core.dht import Ring
from repro.core.limosense import LiMoSenseSimulator
from repro.engine import make_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"))
    ap.add_argument("--peers", type=int, default=2000)
    args = ap.parse_args()

    n = args.peers
    rng = np.random.default_rng(0)
    # the device engine routes on uint32 addresses (d <= 32)
    d = 48 if args.backend == "numpy" else 32
    ring = Ring.random(n, d, seed=0)
    pos = ring.positions()
    up_n, cw_n, ccw_n = A.tree_neighbors_reference(ring.addrs, ring.d)
    print(f"== {n} peers on a {d}-bit ring, engine backend: {args.backend} ==")
    root = int(np.argmin(ring.addrs))
    print(f"root peer: #{root} (owns address 0)")
    i = 42
    print(f"peer #{i}: position {int(pos[i]):012x}, "
          f"UP -> #{up_n[i]}, CW -> #{cw_n[i]}, CCW -> #{ccw_n[i]}")

    votes = np.zeros(n, np.int64)
    votes[rng.choice(n, int(n * 0.35), replace=False)] = 1
    print("\n== local majority voting (Alg. 3) ==")
    sim = make_engine(args.backend, ring, votes, seed=1)
    r = sim.run_until_converged(truth=0)
    print(f"converged in {r['cycles']} cycles, "
          f"{r['messages']/n:.2f} messages/peer")

    print("flipping the electorate: 35% ones -> 65% ones ...")
    new = np.zeros(n, np.int64)
    new[rng.choice(n, int(n * 0.65), replace=False)] = 1
    chg = np.nonzero(new != sim.votes())[0]
    sim.set_votes(chg, new[chg])
    r2 = sim.run_until_converged(truth=1)
    print(f"re-converged in {r2['cycles'] - r['cycles']} cycles, "
          f"{r2['messages']/n:.2f} messages/peer")
    total_local = r["messages"] + r2["messages"]

    print("\n== LiMoSense gossip on the same task ==")
    gos = LiMoSenseSimulator(ring, votes, seed=1)
    g = gos.run_until_converged(truth=0)
    gos.set_votes(np.arange(n), new)
    g2 = gos.run_until_converged(truth=1)
    print(f"gossip: {(g['messages'] + g2['messages'])/n:.2f} messages/peer "
          f"(local thresholding used "
          f"{(g['messages']+g2['messages'])/max(total_local,1):.1f}x fewer)")


if __name__ == "__main__":
    main()
