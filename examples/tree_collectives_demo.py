"""The paper's binary tree as a mesh collective: convergecast/broadcast
via ppermute on 8 (virtual) devices, checked against psum, with the
compiled collective schedule printed.

    PYTHONPATH=src python examples/tree_collectives_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import warnings

warnings.simplefilter("ignore")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.tree_collectives import (
    _parent, shard_map, tree_all_reduce, tree_broadcast, tree_reduce,
)


def main():
    n = 8
    mesh = jax.make_mesh((n,), ("pod",))
    print("== the tree the addressing induces on", n, "pods ==")
    for i in range(n):
        print(f"  pod {i}: parent -> {_parent(i, n)}")

    x = jnp.arange(float(n * 4)).reshape(n, 4)
    ar = shard_map(lambda v: tree_all_reduce(v, "pod", n), mesh=mesh,
                   in_specs=P("pod"), out_specs=P("pod"), check_vma=False)
    out = np.asarray(jax.jit(ar)(x))
    want = np.asarray(x).reshape(n, 1, 4).sum(0)
    print("\ntree all-reduce == sum:", np.allclose(out, np.tile(want, (n, 1))))

    txt = jax.jit(ar).lower(x).compile().as_text()
    print("collective-permutes in the schedule:",
          txt.count("collective-permute("),
          f"(2 x 2 x log2({n}) edges, sibling pairs split)")

    ps = shard_map(lambda v: jnp.broadcast_to(jax.lax.psum(v, "pod"), v.shape),
                   mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                   check_vma=False)
    print("matches psum:", np.allclose(out, np.asarray(jax.jit(ps)(x))))
    print("\nuse: control-plane votes/alerts (threshold sync) ride this tree"
          "\n     in O(log P) hops; bulk gradients keep XLA's ring all-reduce"
          "\n     (DESIGN.md section 6).")


if __name__ == "__main__":
    main()
